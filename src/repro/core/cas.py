"""Content-addressed chunk store (CAS) — the incremental-checkpoint engine.

The paper's key open item is "reducing the checkpoint overhead for
large-scale applications": MANA-style transparent checkpointing pays the
full-state write cost every round. Between adjacent training steps most
leaves (embeddings, frozen layers, optimizer slots of unchanged params) are
byte-identical, so steady-state checkpoints should cost O(changed chunks),
not O(model).

Design:

  * encoded shard payloads are split into chunks — fixed-size by default,
    or content-defined (FastCDC-style, ``core.cdc``) so shifted payloads
    keep deduping; each chunk is stored once under its blake2b digest in
    ``_CAS/objects/<d2>/<digest>.obj`` (immutable, content-addressed — a
    re-write of an existing digest is a dedup hit and costs nothing);
  * the data path is pipelined (``core.chunk_exec``): hash→write fans out
    over a bounded thread pool with ONE directory fsync per payload batch,
    and reassembly prefetches chunks ahead of the consumer; ``io_threads=1``
    degrades to the original serial engine;
  * objects land via write-tmp → fsync → rename, so a crash mid-write leaves
    only ``.tmp-`` litter, never a torn object;
  * ``_CAS/refs.json`` holds the published refcount table (digest → number of
    committed shard references). It is a CACHE: the authoritative root set is
    the chunk lists inside committed step manifests, so any crash that
    staleness-skews refs.json is repaired by the next mark-and-sweep;
  * refcounts are published atomically at COMMIT (by the coordinator's commit
    phase) — an aborted round publishes nothing and its orphaned objects are
    reclaimed by ``sweep``;
  * mark-and-sweep GC: mark = union of chunk refs over every committed
    manifest on every tier, sweep = delete unreferenced objects (and tmp
    litter) from every tier, then republish refs.json from the mark set.

Buddy redundancy mirrors the shard-file story: with ``replicas=2`` every
object is written twice (``.obj`` + ``.obj.r1``) and reads fall back
primary → replica × fast tier → slow tier.
"""
from __future__ import annotations

import hashlib
import json
import os
import secrets
import threading
import zlib
from collections import Counter

from . import atomic, resilience
from .atomic import NO_CRASH, CrashInjector
from .chunk_exec import DEFAULT_IO_THREADS, ChunkIOExecutor, cpu_cap
from .errors import CASError, CorruptShardError, MissingShardError, warn
from .namespace import REPLICA_SUFFIX
from .storage import TieredStore

DEFAULT_CHUNK_SIZE = 1 << 20          # 1 MiB fixed-size chunks
DIGEST_BYTES = 16                     # blake2b-128 — 32 hex chars
CAS_DIR = "_CAS"
OBJECTS_DIR = f"{CAS_DIR}/objects"
REFS_FILE = f"{CAS_DIR}/refs.json"
OBJ_SUFFIX = ".obj"
# corrupt copies are RENAMED here by the scrubber (same tier, single
# atomic rename) — named <digest>.r<replica>.<nonce>.quar so the origin
# slot is recoverable and an interrupted scrub can converge on re-run
QUARANTINE_DIR = f"{CAS_DIR}/quarantine"
HEALTH_FILE = f"{CAS_DIR}/health.json"        # tier health snapshot
SCRUB_FILE = f"{CAS_DIR}/last_scrub.json"     # last scrub summary


def chunk_digest(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=DIGEST_BYTES).hexdigest()


def split_payload(payload: bytes, chunk_size: int):
    """Fixed-size chunking; the final chunk may be short. Empty payloads
    produce no chunks (reassembly yields b'')."""
    return [payload[i:i + chunk_size]
            for i in range(0, len(payload), chunk_size)]


def run_chunker(chunker, payload):
    """Apply a chunker that may be a plain callable (payload → chunk list)
    or a chunker object (``cdc.GearChunker`` — which the save path prefers,
    because the object exposes the async candidate scanner)."""
    if hasattr(chunker, "chunk"):
        return chunker.chunk(payload)
    return chunker(payload)


def object_rel(digest: str, replica: int = 0) -> str:
    rel = f"{OBJECTS_DIR}/{digest[:2]}/{digest}{OBJ_SUFFIX}"
    return rel + REPLICA_SUFFIX if replica else rel


def manifest_chunk_index(manifest: dict, leaf_filter=None) -> dict:
    """Digest → encoded-chunk length for every chunk an (incremental)
    manifest references, optionally restricted to leaves accepted by
    ``leaf_filter(name)``. The weightsync diff: a subscriber subtracts
    its cache-resident set from this index and pulls only the rest.
    Lengths come from ``chunk_lens`` (v5+); ``None`` for older manifests
    (the object's file size is still authoritative on arrival)."""
    index: dict = {}
    for name, rec in manifest.get("leaves", {}).items():
        if leaf_filter is not None and not leaf_filter(name):
            continue
        for s in rec.get("shards", []):
            chunks = s.get("chunks", [])
            lens = s.get("chunk_lens") or [None] * len(chunks)
            for d, n in zip(chunks, lens):
                index[d] = n
    return index


def live_chunk_refs(manifests) -> Counter:
    """Mark phase: refcounts implied by an iterable of manifest dicts —
    one reference per (shard, chunk) occurrence."""
    live: Counter = Counter()
    for manifest in manifests:
        for rec in manifest.get("leaves", {}).values():
            for s in rec.get("shards", []):
                live.update(s.get("chunks", []))
    return live


class ChunkStore:
    """Refcounted, tier-aware object store on top of a TieredStore."""

    def __init__(self, store: TieredStore, *,
                 chunk_size: int = DEFAULT_CHUNK_SIZE, replicas: int = 1,
                 io_threads: int = DEFAULT_IO_THREADS,
                 retry: resilience.RetryPolicy | None = None):
        if chunk_size <= 0:
            raise ValueError("chunk_size must be positive")
        self.store = store
        self.chunk_size = chunk_size
        # buddy redundancy is 2-way, mirroring shard files (one primary +
        # one .r1 copy); higher requests clamp rather than silently writing
        # the same replica path twice
        self.replicas = min(max(int(replicas), 1), 2)
        self._lock = threading.Lock()
        self._inflight: set = set()
        # io_threads > 1 enables the pipelined engine: hash→write fan-out
        # with one directory fsync per payload batch, and prefetched
        # reassembly reads. io_threads <= 1 is byte-for-byte the serial
        # PR-1 path (per-chunk dir fsync, digest-verified gets) — the
        # benchmark baseline.
        self._exec = ChunkIOExecutor(io_threads)
        # retry=None ⇒ every IO is single-attempt fail-fast (the serial
        # engine NEVER constructs a policy — PR-1 purity); the pipelined
        # engine gets the typed budget from DurabilityPolicy.io_*
        self.retry = None if self._exec.serial else retry
        self._deadline: resilience.Deadline | None = None
        # objects written past the fast tier (fail-over under ENOSPC /
        # EROFS) this process — the manifest's `degraded` marker source
        self.degraded_writes = 0

    @classmethod
    def from_policy(cls, store: TieredStore, policy) -> "ChunkStore":
        """The chunk store a ``CheckpointPolicy`` describes: chunk size
        from the chunking section, buddy replicas from durability, pool
        width from the pipeline section, retry budget from durability's
        ``io_*`` trio (pipelined engine only — the ctor drops it for
        ``io_threads=1``)."""
        return cls(store, chunk_size=int(policy.chunking.chunk_size),
                   replicas=policy.durability.replicas,
                   io_threads=policy.pipeline.io_threads,
                   retry=resilience.RetryPolicy.from_durability(
                       policy.durability))

    def begin_io_window(self) -> None:
        """Open one round's shared IO deadline: every retry loop of the
        round (writers, drain, restore reads) draws sleep budget from the
        SAME clock, so the aggregate stall a sick tier can cause is
        bounded by ``io_deadline_s``, not retries × fault sites."""
        if self.retry is not None:
            self._deadline = resilience.Deadline(self.retry.deadline_s)

    def _retry(self, fn, tier, op: str):
        """Bounded retry against one tier, drawing from the round window;
        single-attempt when no policy is set (serial engine)."""
        if self.retry is None:
            return fn()
        return resilience.retry_io(
            fn, self.retry, deadline=self._deadline,
            health=self.store.health_for(tier), op=op)

    # ------------------------------------------------------------------
    # objects
    # ------------------------------------------------------------------
    def exists(self, digest: str) -> bool:
        # only probe the .r1 path when buddy redundancy is configured —
        # with replicas=1 that stat can never hit (writes only ever
        # produce it under replicas=2) and is pure per-chunk overhead
        if self.store.locate(object_rel(digest)) is not None:
            return True
        return self.replicas > 1 and \
            self.store.locate(object_rel(digest, 1)) is not None

    def put(self, digest: str, data: bytes,
            crash: CrashInjector = NO_CRASH) -> int:
        """Store one chunk under its digest with an immediate directory
        fsync. Returns bytes physically written (0 on a dedup hit). Safe
        under concurrent rank writers: the first thread to claim a digest
        writes it; racers dedup."""
        return self._put_one(digest, data, crash, None, None)

    def _put_one(self, digest: str, data: bytes, crash: CrashInjector,
                 dirs: set | None, dirs_lock) -> int:
        """Single-chunk store. With ``dirs`` given, the fan-out directory
        fsync is DEFERRED: the touched parent dir is recorded for the
        caller's batch fsync (one per dir per payload, not one per chunk)."""
        rels = [object_rel(digest, r) for r in range(self.replicas)]
        with self._lock:
            if digest in self._inflight:
                # a prepared-barrier peer (or a pool sibling pipelining the
                # same payload) is writing it
                crash.maybe("cas_dedup_race")
                return 0
            # any copy absent from the FAST tier gets written: brand-new
            # objects, and re-promotion of chunks previously evicted to
            # the slow tier that a new round re-references — a retained
            # step must restore at burst-buffer speed
            to_write = [rel for rel in rels
                        if not (self.store.fast.root / rel).exists()]
            if not to_write:
                crash.maybe("cas_dedup_race")
                return 0
            self._inflight.add(digest)
        written = 0
        try:
            fast = self.store.fast

            def _write_fast(rel):
                # deliberately NOT Tier.write_file(atomic=True): the crash
                # matrix needs an injection point between tmp write and
                # rename, and the object fan-out dir wants an explicit
                # directory fsync after the batch of renames
                tmp = f"{rel}.tmp-{secrets.token_hex(4)}"
                fast.write_file(tmp, data)
                crash.maybe("cas_after_obj_tmp")
                os.rename(fast.root / tmp, fast.root / rel)

            touched_fast = False
            for rel in to_write:
                try:
                    self._retry(lambda: _write_fast(rel), fast,
                                "obj_write")
                    touched_fast = True
                except OSError as e:
                    # the fast tier condemned itself for this round (full /
                    # quota / read-only, retries exhausted): fail over down
                    # the hierarchy instead of aborting the save. Only the
                    # pipelined engine (retry set) degrades — serial stays
                    # fail-fast (PR-1 purity).
                    if self.retry is None or not resilience.is_tier_full(e):
                        raise
                    self._put_degraded(rel, data, e)
                written += len(data)
            if touched_fast:
                parent = (fast.root / rels[0]).parent
                if dirs is None:
                    atomic.fsync_dir(parent)
                else:
                    with dirs_lock:
                        dirs.add(parent)
        finally:
            with self._lock:
                self._inflight.discard(digest)
        return written

    def _put_degraded(self, rel: str, data: bytes, cause: OSError):
        """Degraded-mode object write: the fast tier is full/read-only, so
        land the object on the next healthy tier down (slow → remote) with
        an atomic write + immediate parent-dir fsync (the rare path does
        not batch). The round then commits with a `degraded` manifest
        marker instead of aborting; the chunk reads fine from the lower
        tier and is re-promoted to the fast tier by the next round that
        references it (``_put_one``'s dedup check is fast-tier-only)."""
        fallbacks = [t for t in (self.store.slow, self.store.remote)
                     if t is not None]
        # deprioritize (never skip) tiers whose breaker is open
        fallbacks.sort(key=lambda t:
                       0 if self.store.health_for(t).allow() else 1)
        if not fallbacks:
            raise cause
        last = cause
        for tier in fallbacks:
            try:
                self._retry(
                    lambda: tier.write_file(rel, data, atomic=True),
                    tier, "obj_write")
                atomic.fsync_dir((tier.root / rel).parent)
            except OSError as e:
                last = e
                continue
            with self._lock:
                self.degraded_writes += 1
                first = self.degraded_writes == 1
            self.store.health_for(tier).note("degraded_writes")
            if first:
                warn("CKPT_W_DEGRADED",
                     "fast tier rejected object writes; failing over",
                     tier=tier.name, cause=f"{cause}")
            return
        raise last

    def store_chunk(self, digest: str, data, crash: CrashInjector = NO_CRASH,
                    dirs: set | None = None, dirs_lock=None) -> int:
        """Streaming-writer entry point (``save_path.SaveSession``): store
        one chunk, deferring the fan-out directory fsync into ``dirs`` for
        the caller's rank-level batch barrier. Returns bytes physically
        written (0 on a dedup hit)."""
        return self._put_one(digest, data, crash, dirs, dirs_lock)

    def get(self, digest: str, verify: bool = True) -> bytes:
        """Read one chunk: primary → buddy replica, each fast tier → slow
        tier. Any single copy failing to read (vanished between exists()
        and read — e.g. a concurrent eviction — or EIO) falls through to
        the next copy, like shard replicas do.

        ``verify=False`` skips the per-chunk digest check — only valid
        when the CALLER holds an end-to-end integrity check over the
        reassembled payload (the whole-payload crc32 in every chunked
        shard record) and retries with ``verify=True`` on mismatch. The
        unverified path also probes the fast-tier primary with a direct
        open instead of a stat-then-read (one metadata round-trip per
        chunk on a networked filesystem); any miss falls back to the full
        replica × tier resolution loop.

        Only the CONFIGURED replica slots are probed on the hot path —
        with ``replicas=1`` the old ``range(max(replicas, 2))`` loop paid
        a dead ``.r1`` stat per chunk per tier for paths that can never
        exist. Extra slots left behind by a 2-replica history are still
        honoured, but only as a last resort once every configured slot
        has failed."""
        if not verify:
            try:
                return self.store.fast.read_file(object_rel(digest))
            except OSError:
                pass               # evicted/missing primary: resolve below
        data, last_err = self._resolve(digest, range(self.replicas), verify)
        if data is not None:
            return data
        if self.replicas < 2:
            # last-ditch: a .r1 copy written under an earlier replicas=2
            # config can still save a read whose primary is damaged
            data, extra_err = self._resolve(
                digest, range(self.replicas, 2), verify)
            if data is not None:
                return data
            last_err = last_err or extra_err
        if last_err is not None:
            raise last_err
        raise MissingShardError("chunk object missing on all tiers",
                                digest=digest)

    def _resolve(self, digest: str, replicas, verify: bool):
        """Probe the given replica slots across the tier hierarchy.
        Returns ``(data, None)`` on success, ``(None, last_err)`` when
        every copy was missing/unreadable/corrupt. With a retry policy
        set, each copy read gets its bounded retry, and tiers whose
        breaker is open are deprioritized (tried last, never skipped)."""
        tiers = self.store.tiers()
        if self.retry is not None:
            tiers = sorted(tiers, key=lambda t:
                           0 if self.store.health_for(t).allow() else 1)
        last_err = None
        for replica in replicas:
            rel = object_rel(digest, replica)
            for tier in tiers:
                if not (tier.root / rel).exists():
                    continue
                try:
                    data = self._retry(
                        lambda: tier.read_file(rel), tier, "obj_read")
                except OSError as e:
                    last_err = e
                    continue
                if not verify or chunk_digest(data) == digest:
                    return data, None
                last_err = CorruptShardError(
                    "chunk content does not match its digest",
                    digest=digest, tier=tier.name, replica=replica)
        return None, last_err

    def put_payload(self, payload,
                    crash: CrashInjector = NO_CRASH,
                    on_chunk=None, chunker=None,
                    want_crc: bool = False,
                    dirs_out: set | None = None,
                    lens_out: list | None = None) -> tuple:
        """Chunk + store an encoded shard payload.
        Returns (digest_list, new_bytes_written).

        ``chunker`` (payload → list of chunk bytes) overrides the default
        fixed-size split — content-defined chunking plugs in here.
        ``on_chunk`` is invoked after every stored chunk — writer ranks
        use it to keep their coordinator heartbeat alive through long
        fsync-bound sequences.

        ``want_crc=True`` additionally returns the payload's crc32,
        accumulated chunk-by-chunk in consumption order — in the pipelined
        engine the crc rides for free on the consumer thread while workers
        hash/write the chunks still in flight.

        With ``io_threads > 1`` the hash→write sequence is pipelined
        across the chunk pool and the fan-out directory fsyncs are batched
        to one per directory per payload; the serial engine preserves the
        original chunk-at-a-time, fsync-per-put behaviour. ``payload`` may
        be any buffer (bytes, memoryview, uint8 ndarray) — the pipelined
        save path feeds zero-copy array views.

        ``dirs_out`` (pipelined engine): skip the per-payload directory
        fsync entirely and record touched fan-out dirs into the caller's
        set — a writer rank batching many payloads calls ``fsync_dirs``
        ONCE before acking PREPARED, which is all the durability the
        commit protocol needs (the manifest is written after every rank
        acks; un-fsynced orphans from a crash before that are swept).

        ``lens_out`` (manifest v5): append each chunk's byte length, in
        chunk order — CDC shard records store the list so restore can
        compute every chunk's offset up front and place reads directly.

        The pipelined branch is ``save_path.SaveSession`` limited to one
        payload — ONE implementation of the windowed hash→write pipeline
        (crc folding, dir batching, mid-batch crash point, error-joins-all)
        serves both this call and the rank-wide streaming writer."""
        if self._exec.serial:
            chunks = (run_chunker(chunker, payload) if chunker is not None
                      else split_payload(payload, self.chunk_size))
            digests, new, crc = [], 0, 0
            for chunk in chunks:
                d = chunk_digest(chunk)
                new += self.put(d, chunk, crash)
                digests.append(d)
                if lens_out is not None:
                    lens_out.append(len(chunk))
                if want_crc:
                    crc = zlib.crc32(chunk, crc)
                if on_chunk is not None:
                    on_chunk()
            if want_crc:
                return digests, new, crc & 0xFFFFFFFF
            return digests, new

        from .save_path import SaveSession      # deferred: cas ← save_path
        session = SaveSession(self, crash=crash, on_chunk=on_chunk,
                              chunker=chunker,
                              dirs=dirs_out if dirs_out is not None
                              else set())
        ticket = session.submit_payload(payload)
        if dirs_out is not None:
            session.flush()                     # caller owns the fsync batch
        else:
            session.barrier(crash)
        digests, new, crc = session.result(ticket)
        if lens_out is not None:
            lens_out.extend(ticket.lens)
        if want_crc:
            return digests, new, crc
        return digests, new

    def fsync_dirs(self, dirs, crash: CrashInjector = NO_CRASH):
        """Durability barrier for a batch of object fan-out directories —
        fsyncs fan out over the chunk pool (256-way digest sharding makes
        most dirs distinct, so parallelism is what amortizes them)."""
        crash.maybe("cas_before_batch_fsync")
        self._exec.map_ordered(atomic.fsync_dir, sorted(dirs))

    def read_payload(self, digests, payload_bytes: int | None = None,
                     crc32: int | None = None) -> bytes:
        """Reassemble a payload from its chunk digest list.

        Pipelined engine (``io_threads > 1``) with ``crc32`` given: chunks
        are prefetched ahead of reassembly WITHOUT per-chunk digest checks
        — the whole-payload crc32 is the integrity gate (it covers every
        byte end-to-end), which halves the hashing cost of a restore. On
        any length/crc mismatch the read falls back to fully-verified
        per-chunk fetches, which identify the damaged object and recover
        through buddy replicas / other tiers. The serial engine keeps the
        original digest-verified chunk-at-a-time reads."""
        digests = list(digests)

        def _check(payload: bytes, strict: bool) -> bool:
            if payload_bytes is not None and len(payload) != payload_bytes:
                if strict:
                    raise CorruptShardError(
                        "reassembled payload length mismatch",
                        expected=payload_bytes, got=len(payload))
                return False
            if crc32 is not None and \
                    (zlib.crc32(payload) & 0xFFFFFFFF) != crc32:
                if strict:
                    raise CorruptShardError(
                        "reassembled payload crc mismatch",
                        chunks=len(digests))
                return False
            return True

        if self._exec.serial:
            payload = b"".join(self.get(d) for d in digests)
            _check(payload, strict=True)
            return payload

        # reads are bandwidth/cache bound: cap effective read concurrency
        # near the core count even when the write-side pool is wider
        window = 2 * min(self._exec.threads, cpu_cap())
        fast = crc32 is not None
        payload = b"".join(self._exec.map_ordered(
            lambda d: self.get(d, verify=not fast), digests, window=window))
        if not _check(payload, strict=False):
            # end-to-end check failed: re-read with per-chunk digest
            # verification to pinpoint the damage and engage replica /
            # tier fallback per chunk
            payload = b"".join(self._exec.map_ordered(
                lambda d: self.get(d, verify=True), digests, window=window))
            _check(payload, strict=True)
        return payload

    def read_payload_fixed(self, digests, payload_bytes: int,
                           chunk_size: int, crc32: int) -> bytes | bytearray:
        """Direct-placement reassembly for FIXED chunking (the read-side
        analogue of the write path's zero-copy feed): every chunk's offset
        is known ahead (``i * chunk_size``), so the pipelined engine
        ``readinto``s each chunk straight into a preallocated payload
        buffer — no per-chunk bytes objects, no join copy.

        The serial engine keeps the original join path untouched."""
        digests = list(digests)
        if self._exec.serial or payload_bytes is None or crc32 is None \
                or chunk_size <= 0:
            return self.read_payload(digests, payload_bytes, crc32=crc32)
        if payload_bytes > max(len(digests), 1) * chunk_size or (
                digests and payload_bytes <= (len(digests) - 1) * chunk_size):
            # digest list and claimed length disagree — let the verified
            # path produce the precise corruption error
            return self.read_payload(digests, payload_bytes, crc32=crc32)
        lens = [chunk_size] * len(digests)
        if digests:
            lens[-1] = payload_bytes - (len(digests) - 1) * chunk_size
        return self.read_payload_direct(digests, payload_bytes, crc32, lens)

    def read_payload_direct(self, digests, payload_bytes: int, crc32: int,
                            lens) -> bytes | bytearray:
        """Direct-placement reassembly from an explicit chunk LENGTH list
        (manifest v5): offsets are the prefix sums, so the ``readinto``
        fast path extends to every chunking scheme — content-defined
        chunks land at their exact offsets in a preallocated payload
        buffer with no assemble/join copy. The whole-payload crc32 stays
        the integrity gate; any short/missing/corrupt object drops that
        chunk (or the whole payload, on crc mismatch) back to the
        fully-verified ``read_payload`` path, which pinpoints damage and
        heals via replicas/tiers.

        The serial engine keeps the original join path untouched."""
        digests = list(digests)
        lens = [int(n) for n in lens]
        if self._exec.serial or payload_bytes is None or crc32 is None:
            return self.read_payload(digests, payload_bytes, crc32=crc32)
        if len(lens) != len(digests) or any(n <= 0 for n in lens) \
                or sum(lens) != payload_bytes:
            # length list and digest list disagree — let the verified
            # path produce the precise corruption error
            return self.read_payload(digests, payload_bytes, crc32=crc32)
        offsets = [0]
        for n in lens:
            offsets.append(offsets[-1] + n)
        buf = bytearray(payload_bytes)
        mv = memoryview(buf)
        tiers = self.store.tiers()

        def _fill(i: int):
            dest = mv[offsets[i]:offsets[i + 1]]
            rel = object_rel(digests[i])
            # direct placement walks the full hierarchy — fast, slow, then
            # the cold remote tier's multipart ranged GETs — so a restart
            # with an empty burst buffer still lands chunks straight in
            # the payload buffer with no staged local copy. read_into
            # returns False (never raises) on a missing/short object.
            for tier in tiers:
                if tier.read_into(rel, dest):
                    return
            data = self.get(digests[i], verify=True)
            if len(data) != len(dest):
                raise CorruptShardError(
                    "chunk object length does not match the manifest",
                    digest=digests[i], expected=len(dest), got=len(data))
            dest[:] = data

        window = 2 * min(self._exec.threads, cpu_cap())
        self._exec.map_ordered(_fill, range(len(digests)), window=window)
        if (zlib.crc32(buf) & 0xFFFFFFFF) != crc32:
            # end-to-end gate failed: re-read fully verified, per chunk
            return self.read_payload(digests, payload_bytes, crc32=crc32)
        return buf

    @property
    def executor(self) -> ChunkIOExecutor:
        return self._exec

    def close(self):
        """Tear down the chunk-IO pool (idempotent)."""
        self._exec.shutdown(wait=False)

    # ------------------------------------------------------------------
    # refcounts (published cache; manifests are the root set)
    # ------------------------------------------------------------------
    def load_refs(self) -> dict:
        tier = self.store.locate(REFS_FILE)
        if tier is None:
            return {}
        try:
            return {k: int(v)
                    for k, v in json.loads(tier.read_file(REFS_FILE)).items()}
        except (ValueError, OSError):
            return {}           # torn cache — rebuilt by the next sweep

    def publish_refs(self, refs: dict, crash: CrashInjector = NO_CRASH):
        body = json.dumps({k: v for k, v in sorted(refs.items()) if v > 0},
                          separators=(",", ":")).encode()
        atomic.atomic_write_bytes(self.store.fast.root / REFS_FILE, body,
                                  crash)

    def apply_refs(self, delta, crash: CrashInjector = NO_CRASH) -> dict:
        """COMMIT-phase atomic refcount publication (called by the
        coordinator once a round is durably committed)."""
        with self._lock:
            refs = Counter(self.load_refs())
            refs.update(delta)
            crash.maybe("before_refs_publish")
            self.publish_refs(dict(refs), crash)
            return dict(refs)

    # ------------------------------------------------------------------
    # GC + fsck
    # ------------------------------------------------------------------
    def _iter_objects(self, tier):
        objdir = tier.root / OBJECTS_DIR
        if not objdir.exists():
            return
        for p in sorted(objdir.rglob("*")):
            if p.is_file():
                yield p

    def sweep(self, live: Counter | dict, crash: CrashInjector = NO_CRASH,
              fast_live: Counter | dict | None = None) -> dict:
        """Sweep phase: delete unreferenced objects and tmp litter from
        every tier, then republish refs.json as exactly the mark set.

        `fast_live` (refcounts implied by FAST-tier manifests only) enables
        burst-buffer reclamation — the CAS analogue of ``evict_fast``: a
        fast-tier copy whose only references come from slow-tier history is
        evicted, but strictly only when the identical object file already
        exists on the slow tier, so no live object ever loses its last
        copy. Without it the fast tier would pin every chunk ever
        referenced by any historical step."""
        report = {"swept": 0, "swept_bytes": 0, "kept": 0, "kept_bytes": 0,
                  "tmp_removed": 0, "evicted": 0, "evicted_bytes": 0}
        seen_kept: set = set()
        for tier in self.store.tiers():
            # a crash mid refs.json publication leaves _CAS/refs.json.tmp-*
            # at the CAS top level (outside objects/) — reclaim it here
            cas_dir = tier.root / CAS_DIR
            if cas_dir.exists():
                for t in cas_dir.glob("*.tmp-*"):
                    if t.is_file():
                        tier.delete_file(str(t.relative_to(tier.root)))
                        report["tmp_removed"] += 1
            evictable_tier = (fast_live is not None
                              and tier is self.store.fast
                              and self.store.slow is not None)
            for p in self._iter_objects(tier):
                rel = str(p.relative_to(tier.root))
                if ".tmp-" in p.name:
                    tier.delete_file(rel)
                    report["tmp_removed"] += 1
                    continue
                digest = p.name.split(OBJ_SUFFIX)[0]
                if digest not in live:
                    report["swept"] += 1
                    report["swept_bytes"] += tier.delete_file(rel)
                    crash.maybe("mid_gc_sweep")
                    continue
                if evictable_tier and digest not in fast_live \
                        and self._slow_copy_intact(rel, digest):
                    report["evicted"] += 1
                    report["evicted_bytes"] += tier.delete_file(rel)
                    continue
                if digest not in seen_kept:
                    report["kept"] += 1
                    report["kept_bytes"] += p.stat().st_size
                    seen_kept.add(digest)
        crash.maybe("before_gc_refs_publish")
        self.publish_refs(dict(live), crash)
        return report

    def digests_on_disk(self) -> set:
        out: set = set()
        for tier in self.store.tiers():
            for p in self._iter_objects(tier):
                if ".tmp-" not in p.name:
                    out.add(p.name.split(OBJ_SUFFIX)[0])
        return out

    def _slow_copy_intact(self, rel: str, digest: str) -> bool:
        """Eviction gate: never trust a slow-tier copy by existence alone —
        drains are atomic now, but a copy from an older (non-atomic) writer
        or a damaged disk must not cost the last good replica. Unthrottled
        read: this is an integrity check, not user-visible IO."""
        p = self.store.slow.root / rel
        try:
            return p.is_file() and chunk_digest(p.read_bytes()) == digest
        except OSError:
            return False

    # ------------------------------------------------------------------
    # scrub (bit-rot detection + self-healing)
    # ------------------------------------------------------------------
    def quarantine_entries(self) -> list:
        """Every quarantined copy across the hierarchy:
        ``(tier_name, rel, digest, replica, size)``. Filenames are
        ``<digest>.r<replica>.<nonce>.quar`` — digest and origin slot are
        recoverable from the name alone."""
        out = []
        for tier in self.store.tiers():
            qdir = tier.root / QUARANTINE_DIR
            if not qdir.exists():
                continue
            for p in sorted(qdir.glob("*.quar")):
                parts = p.name.split(".")
                if len(parts) < 4 or not parts[1].startswith("r"):
                    continue
                try:
                    replica = int(parts[1][1:])
                except ValueError:
                    continue
                out.append((tier.name, str(p.relative_to(tier.root)),
                            parts[0], replica, p.stat().st_size))
        return out

    def _object_copies(self, digest: str) -> list:
        """All on-disk copies of one digest: ``(tier, replica, rel)`` for
        every configured-or-legacy slot that exists, across every tier."""
        copies = []
        for replica in range(2):        # legacy .r1 copies heal too
            rel = object_rel(digest, replica)
            for tier in self.store.tiers():
                if (tier.root / rel).is_file():
                    copies.append((tier, replica, rel))
        return copies

    def _read_good(self, digest: str, copies) -> bytes | None:
        """First copy whose content matches its digest (unthrottled direct
        read — scrub is an integrity pass, not user-visible IO)."""
        for tier, _replica, rel in copies:
            try:
                data = (tier.root / rel).read_bytes()
            except OSError:
                continue
            if chunk_digest(data) == digest:
                return data
        return None

    def scrub(self, live: Counter | dict, *, sample: int | None = None,
              seed: int = 0, should_stop=None,
              crash: CrashInjector = NO_CRASH) -> dict:
        """Re-hash live objects and heal what can be healed.

        For every scanned digest: corrupt copies are moved (one atomic
        same-tier rename) to ``_CAS/quarantine/`` and the slot is
        re-written from a good replica/tier — UNLESS no good copy exists
        anywhere, in which case the copy is left in place and counted
        ``unrecoverable`` (never quarantine the last surviving copy; a
        future replica may still surface from an unmounted tier).

        ``sample=N`` re-hashes a seeded N-digest subset (steady-state
        maintenance can amortize a full pass across rounds); the seed
        makes the subset — and therefore the whole report — replayable.
        ``should_stop`` is polled between objects (PreemptionGuard wiring:
        a SIGTERM mid-scrub defers the remainder, and because quarantine
        is one rename and healing is idempotent, the re-run converges).

        Pass 0 re-replicates objects whose quarantine provenance shows a
        slot was emptied but never healed (the crash window between
        rename and re-write) — scrub is convergent under interruption."""
        report = {"scanned": 0, "clean": 0, "healed": 0, "quarantined": 0,
                  "unrecoverable": 0, "deferred": 0, "requarantined": 0,
                  "sample": sample, "seed": seed}

        def _heal(tier, rel: str, data: bytes):
            tier.write_file(rel, data, atomic=True)
            atomic.fsync_dir((tier.root / rel).parent)

        # pass 0: converge interrupted quarantine→heal windows
        quarantined_before = self.quarantine_entries()
        for tier_name, _qrel, digest, replica, _size in quarantined_before:
            if dict(live).get(digest, 0) <= 0:
                continue
            tier = next(t for t in self.store.tiers()
                        if t.name == tier_name)
            rel = object_rel(digest, replica)
            if (tier.root / rel).is_file():
                continue            # slot healed before the interruption
            good = self._read_good(digest, self._object_copies(digest))
            if good is not None:
                _heal(tier, rel, good)
                report["healed"] += 1

        live_digests = sorted(d for d, n in dict(live).items() if n > 0)
        if sample is not None and 0 < sample < len(live_digests):
            import random as _random
            live_digests = sorted(
                _random.Random(seed).sample(live_digests, sample))

        for digest in live_digests:
            if should_stop is not None and should_stop():
                report["deferred"] = len(live_digests) - report["scanned"]
                break
            report["scanned"] += 1
            copies = self._object_copies(digest)
            bad = []
            good_data = None
            for tier, replica, rel in copies:
                try:
                    data = (tier.root / rel).read_bytes()
                except OSError:
                    bad.append((tier, replica, rel))
                    continue
                if chunk_digest(data) == digest:
                    if good_data is None:
                        good_data = data
                else:
                    bad.append((tier, replica, rel))
            if not bad:
                report["clean"] += 1
                continue
            if good_data is None:
                # NEVER quarantine the last surviving copy — leave the
                # damage in place (a replica may yet surface) and report
                report["unrecoverable"] += 1
                warn("CKPT_W_SCRUB",
                     "corrupt chunk with no good copy on any tier",
                     digest=digest, copies=len(copies))
                continue
            for tier, replica, rel in bad:
                qrel = (f"{QUARANTINE_DIR}/{digest}.r{replica}"
                        f".{secrets.token_hex(4)}.quar")
                qpath = tier.root / qrel
                qpath.parent.mkdir(parents=True, exist_ok=True)
                try:
                    os.rename(tier.root / rel, qpath)
                except FileNotFoundError:
                    pass            # unreadable AND vanished: nothing to move
                else:
                    report["quarantined"] += 1
                    self.store.health_for(tier).note("quarantined")
                crash.maybe("scrub_after_quarantine")
                _heal(tier, rel, good_data)
                report["healed"] += 1
        return report

    def fsck(self, live: Counter | dict) -> dict:
        """CAS invariant check against a mark set:
          orphans  — objects on disk not referenced by any committed manifest
          missing  — referenced digests with no readable object anywhere
          ref_drift — refs.json disagrees with the mark set
        Clean ⇔ all three empty."""
        on_disk = self.digests_on_disk()
        live_set = {d for d, n in dict(live).items() if n > 0}
        orphans = sorted(on_disk - live_set)
        missing = []
        for d in sorted(live_set):
            try:
                self.get(d)
            except (MissingShardError, CorruptShardError):
                missing.append(d)
        refs = self.load_refs()
        live_d = dict(live)
        drift = {d: (refs.get(d, 0), live_d.get(d, 0))
                 for d in set(refs) | live_set
                 if refs.get(d, 0) != live_d.get(d, 0)}
        return {"orphans": orphans, "missing": missing, "ref_drift": drift,
                "objects": len(on_disk),
                "ok": not (orphans or missing or drift)}

    def stats(self) -> dict:
        """Unique object count/bytes (primaries, fast tier preferred)."""
        uniq = {}
        for tier in self.store.tiers():
            for p in self._iter_objects(tier):
                if ".tmp-" in p.name or p.name.endswith(REPLICA_SUFFIX):
                    continue
                uniq.setdefault(p.name.split(OBJ_SUFFIX)[0], p.stat().st_size)
        return {"objects": len(uniq), "bytes": sum(uniq.values())}

    def raise_if_inconsistent(self, live) -> None:
        rep = self.fsck(live)
        if not rep["ok"]:
            raise CASError("content-addressed store failed fsck",
                           orphans=len(rep["orphans"]),
                           missing=len(rep["missing"]),
                           ref_drift=len(rep["ref_drift"]))
