"""WeightSync: serving-fleet weight distribution from the CAS —
"checkpoint as transport".

The paper's production lesson is that a C/R substrate must serve more
workloads than the save/restore loop it was prototyped for. The CAS
already makes the manifest diff between two steps a precise byte-level
delta; this module turns that into a live weight-distribution plane so a
fleet of serving replicas hot-swaps weights mid-traffic instead of
cold-restarting:

  WeightPublisher   trainer side. Hooks ``CheckpointManager.on_commit``
                    and, once a round is durable (LATEST moved, refcounts
                    published), writes a step ANNOUNCEMENT — the v7
                    manifest plus step metadata — atomically to every
                    tier of the trainer's store. Announcing is
                    best-effort by design: a failed announce warns and
                    never aborts the committed save.

  WeightSubscriber  serving side. Owns a local chunk cache in CAS layout,
                    mounted as the fast tier of its own read-composed
                    ``TieredStore`` whose lower tiers are peer caches and
                    the source store's tiers. One ``sync()``:

                      diff      manifest chunk index minus cache-resident
                                set — only MISSING chunks move;
                      pull      each missing chunk from the nearest tier
                                (peer before source, breaker-deprioritized,
                                ``retry_io``-wrapped, digest-verified) into
                                the local cache (atomic writes — a killed
                                pull never leaves a torn object);
                      assemble  a SHADOW buffer set via the restore path's
                                own fetch engine (``RestoreSession`` →
                                ``read_payload_direct`` direct placement,
                                whole-payload crc gate, v7 entropy decode)
                                — bit-exact with a fresh ``restore()`` by
                                construction, because it IS the restore
                                read path;
                      flip      one reference assignment under a lock.
                                Readers snapshot ``(step, arrays)`` and can
                                never observe a torn set: the shadow dict
                                is fully built before the pointer moves
                                and no active dict is ever mutated.

                    Any sync failure (faults, missing chunks, a sick
                    source) leaves the ACTIVE set untouched: state becomes
                    ``degraded``, the replica keeps serving the last good
                    weights, and the next ``sync()`` retries from where
                    the cache left off (already-pulled chunks are not
                    re-fetched).

  PeerTier          a read-only ``Tier`` over another replica's cache with
                    a per-request latency — the rack-local hop. N
                    subscribers form a pull tree (``build_fleet``): each
                    replica's peers are caches above it, so the source
                    store serves O(tree root) chunk reads instead of
                    O(fleet) — the thundering-herd guard.

Crash points (``atomic.CrashInjector``): ``ws_mid_pull`` (halfway through
the missing-chunk pulls), ``ws_before_flip`` (shadow built, pointer not
moved), ``ws_after_flip`` (pointer moved, status not yet published). All
three resume idempotently — the cache is the only durable subscriber
state and every write to it is atomic.
"""
from __future__ import annotations

import errno
import json
import threading
import time
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from . import codec as codec_mod
from . import resilience
from .atomic import NO_CRASH, CrashInjector, CrashPoint, committed_dir
from .cas import (OBJ_SUFFIX, OBJECTS_DIR, ChunkStore, chunk_digest,
                  manifest_chunk_index, object_rel)
from .chunk_exec import ChunkIOExecutor, cpu_cap
from .errors import MissingShardError, warn
from .policy import CheckpointPolicy
from .restore_path import ReadCache, RestorePlan, RestoreSession
from .split_state import leaf_paths
from .storage import Tier, TieredStore

ANNOUNCE_REL = "_WS/ANNOUNCE"
SUBSCRIBERS_DIR = "_WS/subscribers"
ANNOUNCE_FORMAT = 1


class _LeafSpec:
    """Shape/dtype carrier standing in for the abstract leaf in a restore
    job — the subscriber plans from the manifest alone, no live pytree."""
    __slots__ = ("shape", "dtype")

    def __init__(self, shape, dtype):
        self.shape = tuple(shape)
        self.dtype = dtype


@dataclass
class PeerTier(Tier):
    """Read-only view of ANOTHER replica's chunk cache, behind a
    per-request latency (the rack-local network hop). Writes are refused
    (EROFS): a replica's cache is owned by that replica alone — peers
    read, they never mutate or reclaim. Bytes served are counted so the
    fan-out benchmark can prove the source was spared."""
    request_latency_s: float = 0.0

    def _request(self):
        if self.request_latency_s > 0:
            time.sleep(self.request_latency_s)

    def _served(self, n: int):
        with self._lock:
            self.io_counters["peer_bytes_served"] = \
                self.io_counters.get("peer_bytes_served", 0) + n

    def write_file(self, rel: str, data: bytes, *, atomic: bool = False):
        raise OSError(errno.EROFS, "peer cache is read-only", rel)

    def delete_file(self, rel: str) -> int:
        raise OSError(errno.EROFS, "peer cache is read-only", rel)

    def read_file(self, rel: str) -> bytes:
        self._request()
        data = super().read_file(rel)
        self._served(len(data))
        return data

    def read_into(self, rel: str, dest) -> bool:
        self._request()
        ok = super().read_into(rel, dest)
        if ok:
            self._served(len(dest))
        return ok


class WeightPublisher:
    """Trainer-side announcement plane. ``attach()`` (or the constructor)
    hooks the manager's ``on_commit`` list; every committed round then
    publishes ``_WS/ANNOUNCE`` — an atomic JSON document carrying the v7
    manifest — to each tier of the trainer's store, so warm subscribers
    find it on the shared fast tier and cold ones on slow/remote."""

    def __init__(self, manager=None, *, store: TieredStore | None = None):
        if manager is None and store is None:
            raise ValueError("WeightPublisher needs a manager or a store")
        self.manager = manager
        self.store = store if store is not None else manager.store
        self.seq = 0
        self.last_announced_step: int | None = None
        if manager is not None:
            self.attach(manager)

    def attach(self, manager) -> "WeightPublisher":
        if self._on_commit not in manager.on_commit:
            manager.on_commit.append(self._on_commit)
        self.manager = manager
        self.store = manager.store
        return self

    def detach(self):
        if self.manager is not None and \
                self._on_commit in self.manager.on_commit:
            self.manager.on_commit.remove(self._on_commit)

    def _on_commit(self, step: int, manifest: dict):
        self.announce(step, manifest)

    def announce(self, step: int, manifest: dict) -> dict:
        """Publish one step announcement. Returns the announcement dict;
        warns (never raises) when a tier refuses the write — distribution
        is best-effort, durability already happened at COMMIT."""
        self.seq += 1
        ann = {
            "format": ANNOUNCE_FORMAT,
            "step": int(step),
            "seq": self.seq,
            "created": time.time(),
            "step_dir": committed_dir(self.store.root, step).name,
            "manifest": manifest,
        }
        data = json.dumps(ann).encode()
        wrote = 0
        for t in (self.store.fast, self.store.slow, self.store.remote):
            if t is None:
                continue
            try:
                t.write_file(ANNOUNCE_REL, data, atomic=True)
                wrote += 1
            except OSError as e:
                warn("CKPT_W_WS", "announce write failed",
                     tier=t.name, step=step,
                     detail=f"{e.__class__.__name__}: {e}")
        if not wrote:
            warn("CKPT_W_WS", "announcement reached no tier", step=step)
        self.last_announced_step = int(step)
        return ann


class WeightSubscriber:
    """Serving-side delta puller + atomic hot-swapper (module docstring
    has the full protocol). One instance per replica; ``sync()`` is
    driven by the serving loop between requests, readers call
    ``current()`` for an un-tearable ``(step, arrays)`` snapshot."""

    def __init__(self, source: TieredStore, cache_dir, *,
                 name: str = "replica0", peers=(), leaf_filter=None,
                 policy: CheckpointPolicy | None = None,
                 crash: CrashInjector = NO_CRASH,
                 publish_status: bool = True):
        policy = policy if policy is not None else CheckpointPolicy()
        self.name = str(name)
        self.source = source
        self.leaf_filter = leaf_filter
        self.crash = crash
        self.publish_status = publish_status
        self.cache = Tier(f"ws-cache-{self.name}", Path(cache_dir))
        self.peer_tiers = [p.as_peer_tier() if isinstance(p, WeightSubscriber)
                           else p for p in peers]
        # the composed read view: local cache first, then peers, then the
        # source store's own fast→slow→remote order. Writes (pulled
        # chunks) only ever touch the cache tier.
        self.store = TieredStore(
            self.cache, None, peers=[*self.peer_tiers, *source.tiers()])
        self.chunks = ChunkStore.from_policy(self.store, policy)
        self.retry = resilience.RetryPolicy.from_durability(
            policy.durability)
        io_threads = policy.pipeline.io_threads
        # leaf fan-out on its own pool (restore-path rule: leaf tasks wait
        # on chunk reads, sharing the chunk pool could deadlock)
        self._restore_exec = ChunkIOExecutor(
            min(io_threads, cpu_cap()) if io_threads > 1 else io_threads)
        self._session = RestoreSession(
            self.store, self.chunks, self._restore_exec,
            ReadCache(policy.pipeline.read_cache_bytes))
        self._lock = threading.Lock()
        self._arrays: dict = {}
        self.flipped_step: int | None = None
        self.state = "init"                 # init | live | degraded
        self.last_error: str | None = None
        self._ctr_lock = threading.Lock()
        self.counters = {"syncs": 0, "flips": 0, "sync_failures": 0,
                         "chunks_pulled": 0, "wire_bytes": 0,
                         "peer_bytes": 0, "source_bytes": 0,
                         "pull_corrupt": 0, "last_sync_s": 0.0,
                         "last_flip_blocking_s": 0.0}

    # -- fleet plumbing -------------------------------------------------
    def as_peer_tier(self, *, latency_s: float = 0.0,
                     bw: float | None = None) -> PeerTier:
        return PeerTier(f"ws-peer-{self.name}", self.cache.root,
                        bw_bytes_per_s=bw, request_latency_s=latency_s)

    # -- announcement plane ---------------------------------------------
    def poll(self) -> dict | None:
        """Latest announcement visible on any SOURCE tier, or None."""
        for t in self.source.tiers():
            try:
                if (t.root / ANNOUNCE_REL).exists():
                    return json.loads(t.read_file(ANNOUNCE_REL).decode())
            except (OSError, ValueError):
                continue
        return None

    # -- the sync protocol ----------------------------------------------
    def sync(self, announcement: dict | None = None) -> dict:
        """Diff → pull → assemble → flip, against `announcement` (or the
        latest polled one). Holds last-good on ANY failure: the active
        set is untouched, state goes ``degraded``, and the next call
        resumes from the cache. Returns ``status()``."""
        ann = announcement if announcement is not None else self.poll()
        if ann is None:
            return self.status()
        step = int(ann["step"])
        if self.flipped_step is not None and step <= self.flipped_step:
            return self.status()
        t0 = time.monotonic()
        with self._ctr_lock:
            self.counters["syncs"] += 1
        try:
            manifest = ann["manifest"]
            if manifest.get("mode") != "incremental":
                raise ValueError(
                    "weightsync requires incremental (CAS) checkpoints; "
                    f"announced mode={manifest.get('mode')!r}")
            index = manifest_chunk_index(manifest, self.leaf_filter)
            missing = [d for d in index
                       if not (self.cache.root / object_rel(d)).exists()]
            self._pull(missing, index)
            shadow = self._assemble(manifest, ann["step_dir"])
            self.crash.maybe("ws_before_flip")
            self._flip(step, shadow)
            self.crash.maybe("ws_after_flip")
        except CrashPoint:
            raise                           # a simulated kill is a kill
        except Exception as e:
            with self._ctr_lock:
                self.counters["sync_failures"] += 1
            if self._arrays:
                self.state = "degraded"     # hold-last-good
            self.last_error = f"{e.__class__.__name__}: {e}"
            warn("CKPT_W_WS", "weight sync failed; holding last good set",
                 subscriber=self.name, step=step, detail=self.last_error)
        finally:
            with self._ctr_lock:
                self.counters["last_sync_s"] = time.monotonic() - t0
        self._publish_status()
        return self.status()

    def _pull(self, missing, index) -> int:
        """Fetch every missing chunk into the local cache. Atomic per
        object; killed mid-pull, the cache keeps whatever landed and the
        next sync's diff shrinks accordingly."""
        if not missing:
            return 0
        halfway = max(len(missing) // 2, 1)
        done_box = [0]

        def pull_one(digest):
            n, kind = self._pull_one(digest)
            with self._ctr_lock:
                self.counters["chunks_pulled"] += 1
                self.counters["wire_bytes"] += n
                self.counters[kind] += n
                done_box[0] += 1
                done = done_box[0]
            if done == halfway:
                self.crash.maybe("ws_mid_pull")
            return n

        self.chunks.begin_io_window()
        self.chunks.executor.map_ordered(pull_one, list(missing))
        return len(missing)

    def _pull_one(self, digest: str) -> tuple:
        """One chunk: nearest tier that has it (peer before source, an
        open breaker deprioritized), retry-wrapped read, digest gate,
        atomic cache write. Returns (bytes, 'peer_bytes'|'source_bytes')."""
        rels = [object_rel(digest, r) for r in range(2)]  # probe buddy too
        tiers = [t for t in self.store.tiers() if t is not self.cache]
        tiers = sorted(
            tiers, key=lambda t: 0 if self.store.health_for(t).allow()
            else 1)
        last_err = None
        for t in tiers:
            for rel in rels:
                if not (t.root / rel).exists():
                    continue
                try:
                    data = resilience.retry_io(
                        lambda: t.read_file(rel), self.retry,
                        deadline=self.chunks._deadline,
                        health=self.store.health_for(t), op="ws_pull")
                except OSError as e:
                    last_err = e
                    continue
                if chunk_digest(data) != digest:
                    with self._ctr_lock:
                        self.counters["pull_corrupt"] += 1
                    last_err = MissingShardError(
                        "chunk digest mismatch on pull",
                        digest=digest, tier=t.name)
                    continue
                self.cache.write_file(object_rel(digest), data,
                                      atomic=True)
                kind = "peer_bytes" if str(t.name).startswith("ws-peer") \
                    else "source_bytes"
                return len(data), kind
        raise last_err if last_err is not None else MissingShardError(
            "chunk unavailable on any tier", digest=digest)

    def _assemble(self, manifest: dict, step_dir: str) -> dict:
        """Shadow buffer set via the restore path's own fetch engine —
        the bit-exactness argument is structural: these ARE the reads and
        decodes a fresh ``restore()`` would perform, resolved against the
        now-populated local cache."""
        jobs = []
        for name, rec in manifest.get("leaves", {}).items():
            if self.leaf_filter is not None and not self.leaf_filter(name):
                continue
            sds = _LeafSpec(rec["shape"], rec["dtype"])
            jobs.append((name, rec, sds, None,
                         codec_mod._np_dtype(rec["dtype"])))
        plan = RestorePlan(jobs, step_dir)
        self.chunks.begin_io_window()
        fetched = self._restore_exec.map_ordered(
            lambda job: self._session.fetch_host(plan.step_dir, job), jobs)
        shadow = {}
        for job, pre in zip(jobs, fetched):
            name, _, sds, _, _ = job
            # reshape, not ascontiguousarray: the latter silently promotes
            # 0-d (scalar leaves) to 1-d
            shadow[name] = np.asarray(
                pre[((0,) * len(sds.shape), sds.shape)]).reshape(sds.shape)
        return shadow

    def _flip(self, step: int, shadow: dict):
        """The atomic swap: one reference assignment under the lock.
        Blocking cost is O(1) — no reader ever waits on IO here."""
        t0 = time.monotonic()
        with self._lock:
            self._arrays = shadow
            self.flipped_step = int(step)
            self.state = "live"
            self.last_error = None
        with self._ctr_lock:
            self.counters["flips"] += 1
            self.counters["last_flip_blocking_s"] = time.monotonic() - t0

    # -- the serving surface --------------------------------------------
    def current(self) -> tuple:
        """(step, {leaf name → host array}) — an un-tearable snapshot:
        the dict was fully built before the pointer moved and is never
        mutated after."""
        with self._lock:
            return self.flipped_step, self._arrays

    # -- introspection ---------------------------------------------------
    def cache_residency(self) -> dict:
        chunks = 0
        nbytes = 0
        objects = self.cache.root / OBJECTS_DIR
        if objects.is_dir():
            for p in objects.glob(f"*/*{OBJ_SUFFIX}"):
                try:
                    nbytes += p.stat().st_size
                    chunks += 1
                except OSError:
                    continue
        return {"chunks": chunks, "bytes": nbytes}

    def status(self) -> dict:
        res = self.cache_residency()
        with self._ctr_lock:
            counters = dict(self.counters)
        return {"name": self.name, "state": self.state,
                "last_flipped_step": self.flipped_step,
                "cache_chunks": res["chunks"],
                "cache_bytes": res["bytes"],
                "cache_dir": str(self.cache.root),
                "last_error": self.last_error,
                "counters": counters,
                "updated_at": time.time()}

    def _publish_status(self):
        """Best-effort per-replica status on the SOURCE fast tier —
        ``inspect_ckpt --subscribers`` reads these."""
        if not self.publish_status:
            return
        try:
            self.source.fast.write_file(
                f"{SUBSCRIBERS_DIR}/{self.name}.json",
                json.dumps(self.status()).encode(), atomic=True)
        except OSError as e:
            warn("CKPT_W_WS", "subscriber status publish failed",
                 subscriber=self.name,
                 detail=f"{e.__class__.__name__}: {e}")

    def close(self):
        for ex in (self.chunks.executor, self._restore_exec):
            try:
                ex.shutdown(wait=True)
            except Exception:  # noqa: BLE001 — teardown is best-effort
                pass


def build_fleet(source: TieredStore, root, n: int, *, fanout: int = 2,
                policy: CheckpointPolicy | None = None, leaf_filter=None,
                peer_latency_s: float = 0.0,
                name_prefix: str = "replica") -> list:
    """N subscribers in a pull tree of arity `fanout`: replica i's peer
    is replica (i-1)//fanout, so only the tree root leans on the source
    store — the rest pull rack-locally."""
    if n < 1:
        raise ValueError("fleet needs at least one replica")
    root = Path(root)
    subs: list[WeightSubscriber] = []
    for i in range(n):
        peers = []
        if i > 0:
            parent = subs[(i - 1) // max(int(fanout), 1)]
            peers = [parent.as_peer_tier(latency_s=peer_latency_s)]
        subs.append(WeightSubscriber(
            source, root / f"{name_prefix}{i}", name=f"{name_prefix}{i}",
            peers=peers, policy=policy, leaf_filter=leaf_filter))
    return subs


def assert_bitexact(arrays: dict, state, leaf_filter=None):
    """Leaf-by-leaf bit-equality between a subscriber's active set and a
    restored pytree — the acceptance gate tests and every bench rep run.
    Byte-compares (dtype-safe for bfloat16 et al.) and checks coverage
    both ways."""
    want = {name: np.asarray(leaf) for name, leaf in leaf_paths(state)
            if leaf_filter is None or leaf_filter(name)}
    missing = sorted(set(want) - set(arrays))
    extra = sorted(set(arrays) - set(want))
    if missing or extra:
        raise AssertionError(
            f"leaf set mismatch: missing={missing} extra={extra}")
    for name, ref in want.items():
        got = arrays[name]
        if tuple(got.shape) != tuple(ref.shape) or \
                str(got.dtype) != str(ref.dtype):
            raise AssertionError(
                f"{name}: shape/dtype mismatch "
                f"{got.shape}/{got.dtype} vs {ref.shape}/{ref.dtype}")
        if got.tobytes() != ref.tobytes():
            raise AssertionError(f"{name}: payload bytes differ")
