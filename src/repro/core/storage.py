"""Tiered checkpoint storage: burst buffer (fast, node-local) + scratch
(slow, shared) — the Cori DataWarp-vs-Lustre hierarchy from the paper's
Fig 2 — plus an optional cold OBJECT-STORE tier (``RemoteTier``) behind
S3-style request latency and multipart ranged GETs, so cold restarts can
pull straight from object storage with no staged local copy.

On this box the "burst buffer" is /dev/shm (RAM-backed, real), "scratch"
is disk behind a token-bucket bandwidth throttle, and the "object store"
is a local directory behind per-request latency + the same token bucket,
so the paper's measured hierarchy (>20× checkpoint, ~2.5× restart) is
reproducible deterministically.

Also implements the paper's P8: capacity preflight with a coded warning/error
instead of a mid-write failure.
"""
from __future__ import annotations

import os
import secrets
import shutil
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path

from .errors import SpaceError, warn


@dataclass
class Tier:
    name: str
    root: Path
    bw_bytes_per_s: float | None = None     # None = unthrottled
    capacity_bytes: int | None = None       # None = filesystem free space

    def __post_init__(self):
        self.root = Path(self.root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()
        self._bucket = 0.0
        self._last = time.monotonic()
        self._used = 0
        # read-failure accounting: a dying disk must be VISIBLE (one
        # rate-limited warn per (kind, rel)) instead of silently absorbed
        # by the verified-fallback path; counters feed the health report
        self.io_counters: dict = {}
        self._warned_reads: set = set()

    # --- capacity ---
    def free_bytes(self) -> int:
        if self.capacity_bytes is not None:
            return max(self.capacity_bytes - self._used, 0)
        st = os.statvfs(self.root)
        return st.f_bavail * st.f_frsize

    def preflight(self, required_bytes: int, *, headroom: float = 1.1):
        """Paper P8: warn at <2× requirement, fail below the requirement."""
        free = self.free_bytes()
        need = int(required_bytes * headroom)
        if free < need:
            raise SpaceError("insufficient space for checkpoint image",
                             tier=self.name, free=free, required=need)
        if free < 2 * need:
            warn("CKPT_W_SPACE", "checkpoint space headroom below 2x",
                 tier=self.name, free=free, required=need)

    # --- throttled IO ---
    def _throttle(self, nbytes: int):
        if not self.bw_bytes_per_s:
            return
        with self._lock:
            now = time.monotonic()
            self._bucket = min(self._bucket + (now - self._last)
                               * self.bw_bytes_per_s, self.bw_bytes_per_s)
            self._last = now
            self._bucket -= nbytes
            deficit = -self._bucket
        if deficit > 0:
            time.sleep(deficit / self.bw_bytes_per_s)

    def write_file(self, rel: str, data: bytes, *, atomic: bool = False):
        """`atomic=True` writes through a tmp name + rename so a torn write
        can never be mistaken for a complete file (drain copies use this —
        readers trust slow-tier files by existence)."""
        path = self.root / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        dst = path.with_name(
            path.name + f".tmp-{secrets.token_hex(4)}") if atomic else path
        try:
            # overwrite frees the old bytes: charging len(data) on top of
            # the prior charge would drift _used upward every rewrite
            # (LATEST, _CAS/refs.json) until a capacity-capped tier hits
            # false CKPT_W_SPACE warnings and spurious SpaceError preflights
            prior = path.stat().st_size
        except OSError:
            prior = 0
        chunk = 4 << 20
        with open(dst, "wb") as f:
            for i in range(0, len(data), chunk):
                piece = data[i:i + chunk]
                self._throttle(len(piece))
                f.write(piece)
            f.flush()
            os.fsync(f.fileno())
        if atomic:
            os.rename(dst, path)
        with self._lock:
            self._used = max(self._used + len(data) - prior, 0)
        return path

    def read_file(self, rel: str) -> bytes:
        path = self.root / rel
        data = path.read_bytes()
        self._throttle(len(data))
        return data

    def read_into(self, rel: str, dest: memoryview) -> bool:
        """Direct-placement read: fill `dest` from the file without an
        intermediate bytes object. True iff the file length matched the
        destination exactly; False on a mismatch (truncated or over-long
        object) AND on any OSError — a vanished/unreadable file must send
        the caller to the verified-fallback path, never crash a restore
        pool worker. The False paths are NOT conflated though: a missing
        file (normal tier fallthrough) only bumps ``read_missing``, while
        a short read or a real IO error is counted separately and warned
        once per ``(kind, rel)`` — a dying disk stays visible even when
        every read is absorbed downstream. Bytes actually read pay the
        token bucket BEFORE the return either way (like ``read_file``),
        so short reads cannot bypass the bandwidth model the io-sweep
        A/B depends on."""
        path = self.root / rel
        n = 0
        try:
            with open(path, "rb") as f:
                n = f.readinto(dest) or 0
                ok = n == len(dest) and not f.read(1)
        except FileNotFoundError:
            # expected during tier fallthrough — count, never warn
            with self._lock:
                self.io_counters["read_missing"] = \
                    self.io_counters.get("read_missing", 0) + 1
            return False
        except OSError as e:
            self._throttle(n)
            self._note_read_failure(rel, f"{e.__class__.__name__}: {e}",
                                    "read_error")
            return False
        self._throttle(n)
        if not ok:
            self._note_read_failure(
                rel, f"length mismatch: read {n}, wanted {len(dest)}",
                "short_read")
        return ok

    def _note_read_failure(self, rel: str, detail: str, kind: str):
        """Count a non-missing read failure and warn ONCE per
        ``(kind, rel)`` (dedup set capped so a sweep over a corrupt tree
        cannot grow it unboundedly)."""
        key = (kind, rel)
        with self._lock:
            self.io_counters[kind] = self.io_counters.get(kind, 0) + 1
            if key in self._warned_reads:
                return
            if len(self._warned_reads) < 256:
                self._warned_reads.add(key)
        warn("CKPT_W_READ", f"tier read failed ({kind})",
             tier=self.name, rel=rel, detail=detail)

    def sweep_tmp_litter(self) -> int:
        """Remove orphaned ``.tmp-*`` FILES under this tier's root — the
        litter a crash inside an ``atomic=True`` write (or
        ``atomic_write_bytes``) leaves behind, which no commit path ever
        revisits. Staging *directories* (``step_*.tmp-*/``) are skipped:
        ``atomic.gc_staging`` owns those wholesale. Returns files removed.

        Callers must ensure no atomic write is in flight on this tier
        (``run_maintenance`` runs post-drain on the persist thread)."""
        removed = 0
        for p in self.root.rglob("*.tmp-*"):
            if not p.is_file():
                continue
            if any(".tmp-" in part for part in
                   p.relative_to(self.root).parts[:-1]):
                continue        # inside a staging dir: gc_staging territory
            try:
                p.unlink()
                removed += 1
            except OSError:
                pass
        return removed

    def delete_file(self, rel: str) -> int:
        """Remove a file, returning the bytes freed (0 if absent)."""
        path = self.root / rel
        try:
            nbytes = path.stat().st_size
            path.unlink()
        except FileNotFoundError:
            return 0
        with self._lock:
            self._used = max(self._used - nbytes, 0)
        return nbytes


DEFAULT_REMOTE_PART_BYTES = 8 << 20
DEFAULT_REMOTE_LATENCY_S = 0.0


@dataclass
class RemoteTier(Tier):
    """S3-style cold object store, simulated on a local directory: every
    request (GET / ranged GET / HEAD) pays ``request_latency_s`` before any
    bytes flow, bytes pay the inherited token bucket, and reads larger than
    ``part_bytes`` are issued as MULTIPART ranged GETs (each part its own
    request) — the access model of `aws s3api get-object --range` that a
    cold restart streams against.

    PUTs are always atomic (an object either exists in full or not at
    all — there are no torn objects in an object store), whatever the
    caller passed for ``atomic``."""
    request_latency_s: float = DEFAULT_REMOTE_LATENCY_S
    part_bytes: int = DEFAULT_REMOTE_PART_BYTES

    def __post_init__(self):
        super().__post_init__()
        if int(self.part_bytes) <= 0:
            raise ValueError("part_bytes must be positive")

    def _request(self):
        if self.request_latency_s > 0:
            time.sleep(self.request_latency_s)

    def write_file(self, rel: str, data: bytes, *, atomic: bool = True):
        self._request()                     # one PUT round-trip
        return super().write_file(rel, data, atomic=True)

    def read_range(self, rel: str, dest: memoryview, offset: int) -> bool:
        """ONE ranged GET: fill `dest` from `offset`. False on any OSError
        or short read (the verified-fallback contract of ``read_into``)."""
        self._request()
        n = 0
        try:
            with open(self.root / rel, "rb") as f:
                f.seek(offset)
                n = f.readinto(dest) or 0
        except FileNotFoundError:
            with self._lock:
                self.io_counters["read_missing"] = \
                    self.io_counters.get("read_missing", 0) + 1
            return False
        except OSError as e:
            self._note_read_failure(rel, f"{e.__class__.__name__}: {e}",
                                    "read_error")
            return False
        self._throttle(n)
        if n != len(dest):
            self._note_read_failure(
                rel, f"ranged GET short: read {n}, wanted {len(dest)} "
                     f"at offset {offset}", "short_read")
        return n == len(dest)

    def read_into(self, rel: str, dest: memoryview) -> bool:
        """Whole-object direct placement as multipart ranged GETs. A size
        mismatch is detected from the object stat (the HEAD every GET
        response carries) before any part is fetched."""
        path = self.root / rel
        try:
            size = path.stat().st_size
        except FileNotFoundError:
            with self._lock:
                self.io_counters["read_missing"] = \
                    self.io_counters.get("read_missing", 0) + 1
            return False
        except OSError as e:
            self._note_read_failure(rel, f"{e.__class__.__name__}: {e}",
                                    "read_error")
            return False
        mv = memoryview(dest)
        if size != len(mv):
            self._note_read_failure(
                rel, f"size mismatch: object {size}, wanted {len(mv)}",
                "short_read")
            return False
        for off in range(0, len(mv), int(self.part_bytes)):
            if not self.read_range(rel, mv[off:off + int(self.part_bytes)],
                                   off):
                return False
        return True

    def read_file(self, rel: str) -> bytes:
        from .resilience import RemoteInconsistencyError
        size = (self.root / rel).stat().st_size   # raises if absent
        buf = bytearray(size)
        if not self.read_into(rel, memoryview(buf)):
            # typed (EIO) so retry_io / is_transient re-issue the GET
            # instead of treating a stale HEAD as a permanent error
            raise RemoteInconsistencyError(
                f"remote object changed mid-read: {rel}", rel=rel,
                kind="stale_head")
        return bytes(buf)


def mirror_to_tier(src: Tier, dst: Tier) -> int:
    """Copy every committed file under `src` to `dst` (atomic writes;
    ``.tmp-*`` litter and staging dirs skipped) — the stand-in for the
    out-of-band sync (`aws s3 sync`) that populates a ``RemoteTier`` from
    a drained checkpoint root. Returns files copied."""
    copied = 0
    for p in sorted(src.root.rglob("*")):
        if not p.is_file() or ".tmp-" in str(p.relative_to(src.root)):
            continue
        dst.write_file(str(p.relative_to(src.root)), p.read_bytes(),
                       atomic=True)
        copied += 1
    return copied


class TieredStore:
    """Writes land on the fast tier; committed checkpoints drain to the slow
    tier in the background (real burst-buffer semantics). Reads prefer fast,
    then slow, then the cold ``remote`` object-store tier — a cold restart
    with an empty burst buffer resolves every read straight off the remote
    tier's ranged GETs, no staged local copy."""

    def __init__(self, fast: Tier, slow: Tier | None = None,
                 drain_async: bool = True, io_executor=None,
                 remote: Tier | None = None, peers=()):
        self.fast = fast
        self.slow = slow
        self.remote = remote
        # read-only sibling caches (weightsync peer fan-out): resolved
        # after fast but before slow/remote, so a subscriber prefers a
        # rack-local replica over hammering the shared store. Never
        # written, never drained, never swept.
        self.peers = list(peers)
        self.drain_async = drain_async
        # optional ChunkIOExecutor: drain copies fan out over it so the
        # read side (fast tier) overlaps the throttled write side (slow
        # tier). CheckpointManager shares its chunk pool here.
        self.io_executor = io_executor
        self._drainer: threading.Thread | None = None
        self._drain_err = None
        # drains deferred because the slow tier's breaker was open:
        # (step_dir_name, rels) jobs held back — never dropped — until
        # the breaker half-opens or ``wait_drained`` forces them through
        self._drain_pending: list = []
        # resilience plumbing (wired by CheckpointManager): io_retry is a
        # resilience.RetryPolicy on the pipelined engine, None on the
        # serial engine (fail-fast — PR-1 purity); _health maps tier name
        # → TierHealth, created lazily so bare stores cost nothing
        self.io_retry = None
        self._health: dict = {}
        self._health_lock = threading.Lock()

    @property
    def root(self) -> Path:
        return self.fast.root

    def health_for(self, tier) -> "resilience_mod.TierHealth":
        """The (lazily created) ``TierHealth`` for a mounted tier; accepts
        the tier object or its name."""
        from . import resilience as resilience_mod
        name = tier if isinstance(tier, str) else tier.name
        with self._health_lock:
            h = self._health.get(name)
            if h is None:
                h = self._health[name] = resilience_mod.TierHealth(name)
            return h

    def health_report(self) -> dict:
        """Snapshot of every mounted tier's health: breaker state + error/
        retry counters (including the tier-level read-failure counters from
        ``_note_read_failure``) — the payload of ``_CAS/health.json``."""
        report = {}
        for t in self.tiers():
            snap = self.health_for(t).snapshot()
            for k, v in getattr(t, "io_counters", {}).items():
                snap["counters"][k] = snap["counters"].get(k, 0) + v
            report[t.name] = snap
        return report

    def apply_pipeline_policy(self, pipeline) -> "TieredStore":
        """Adopt a ``PipelinePolicy``'s drain mode. ``async_drain=None``
        (the default) leaves the store as constructed — the policy only
        overrides what it explicitly sets, so a store built with
        ``drain_async=False`` isn't silently flipped by a default
        policy."""
        if getattr(pipeline, "async_drain", None) is not None:
            self.drain_async = bool(pipeline.async_drain)
        return self

    def apply_restore_policy(self, restore) -> "TieredStore":
        """Adopt a ``RestorePolicy``'s remote-read shape (multipart ranged
        GET size) onto the remote tier, if one is mounted."""
        part = getattr(restore, "remote_part_bytes", None)
        if self.remote is not None and part:
            self.remote.part_bytes = int(part)
        return self

    def tiers(self):
        return [t for t in (self.fast, *self.peers, self.slow, self.remote)
                if t is not None]

    def _drain_one(self, step_dir_name: str, rels):
        """Copy ONE committed step dir (plus its CAS objects) fast→slow.
        Runs on the drainer thread (or inline for sync/forced drains)."""
        src = self.fast.root / step_dir_name

        def _slow_write(rel, data):
            if self.io_retry is None:
                self.slow.write_file(rel, data, atomic=True)
                return
            from . import resilience
            resilience.retry_io(
                lambda: self.slow.write_file(rel, data, atomic=True),
                self.io_retry, health=self.health_for(self.slow),
                op="drain_write")

        def _copy_extra(rel):
            f = self.fast.root / rel
            if f.is_file() and not (self.slow.root / rel).exists():
                _slow_write(rel, f.read_bytes())

        def _copy_step(p):
            rel = str(Path(step_dir_name) / p.relative_to(src))
            _slow_write(rel, p.read_bytes())

        # a drain killed mid-write leaves .tmp- litter in slow-tier
        # step dirs that nothing else walks (gc_staging covers the
        # fast root, the CAS sweep covers _CAS) — purge it here,
        # off the save path; drains are serialized so no live tmp
        # file can be hit
        for t in self.slow.root.glob("step_*/**/*.tmp-*"):
            try:
                t.unlink()
            except OSError:
                pass
        step_files = [p for p in sorted(src.rglob("*")) if p.is_file()]
        ex = self.io_executor
        if ex is not None and not ex.serial:
            # two batches with a barrier between them: CAS objects
            # must be fully landed before the step dir (and its
            # manifest) can reference them on the slow tier
            ex.map_ordered(_copy_extra, rels)
            ex.map_ordered(_copy_step, step_files)
        else:
            for rel in rels:
                _copy_extra(rel)
            for p in step_files:
                _copy_step(p)

    def _run_drain_jobs(self, jobs):
        try:
            for step_dir_name, rels in jobs:
                self._drain_one(step_dir_name, rels)
        except Exception as e:  # noqa
            self._drain_err = e

    def drain_step(self, step_dir_name: str, extra_files=()):
        """Copy a committed checkpoint dir fast→slow (throttled) on ONE
        background thread, preceded by `extra_files` (CAS chunk objects
        live outside step directories). All copies are atomic writes, so a
        killed drain never leaves a torn file under a trusted name.

        Breaker-aware: if the slow tier's circuit breaker is OPEN (a run
        of consecutive drain-write failures), the copy is DEFERRED — held
        on a pending queue, never dropped — and retried on the next drain
        (by which time the breaker has half-opened) or forced through by
        ``wait_drained``/``evict_fast``. Deprioritize, never skip: a sick
        scratch filesystem delays durability, it must not silently lose
        the slow-tier copy a later eviction assumes exists."""
        if self.slow is None:
            return
        rels = [r for r in extra_files if (self.fast.root / r).is_file()]
        job = (step_dir_name, rels)
        if not self.drain_async:
            self._run_drain_jobs([job])
            return
        # serialize with any in-flight drain (raises a prior drain error
        # here, on the save path, like it always has)
        self._join_drainer()
        self._drain_pending.append(job)
        if not self.health_for(self.slow).allow():
            self.health_for(self.slow).note("drain_deferred")
            warn("CKPT_W_DRAIN", "slow-tier breaker open: drain deferred",
                 tier=self.slow.name, step=step_dir_name,
                 pending=len(self._drain_pending))
            return
        jobs, self._drain_pending = self._drain_pending, []
        self._drainer = threading.Thread(
            target=self._run_drain_jobs, args=(jobs,), daemon=True)
        self._drainer.start()

    def _join_drainer(self):
        if self._drainer is not None:
            self._drainer.join()
            self._drainer = None
        if self._drain_err is not None:
            e, self._drain_err = self._drain_err, None
            raise e

    def wait_drained(self):
        """Join the in-flight drain AND force any breaker-deferred copies
        through inline — after this returns (without raising), every
        requested drain has landed on the slow tier."""
        self._join_drainer()
        while self._drain_pending:
            jobs, self._drain_pending = self._drain_pending, []
            self._run_drain_jobs(jobs)
            self._join_drainer()    # re-raise anything _run_drain_jobs caught

    def locate(self, rel: str) -> Tier | None:
        for t in self.tiers():
            if (t.root / rel).exists():
                return t
        return None

    def evict_fast(self, step_dir_name: str):
        """Free burst-buffer space once a step is safely on the slow tier."""
        if self.slow is None:
            return
        self.wait_drained()
        shutil.rmtree(self.fast.root / step_dir_name, ignore_errors=True)


def default_store(workdir: str | Path, *, burst_buffer: bool = True,
                  lustre_bw: float | None = 500e6,
                  remote_dir: str | Path | None = None,
                  remote_bw: float | None = None,
                  remote_latency_s: float = DEFAULT_REMOTE_LATENCY_S) \
        -> TieredStore:
    """fast = /dev/shm (if available), slow = <workdir>/scratch (throttled),
    plus an optional cold object-store tier when `remote_dir` is given."""
    workdir = Path(workdir)
    shm = Path("/dev/shm")
    if burst_buffer and shm.exists() and os.access(shm, os.W_OK):
        fast = Tier("burst-buffer", shm / f"repro-bb-{os.getpid()}" /
                    workdir.name)
    else:
        fast = Tier("local", workdir / "bb")
    slow = Tier("scratch-sim", workdir / "scratch", bw_bytes_per_s=lustre_bw)
    remote = None
    if remote_dir is not None:
        remote = RemoteTier("object-store", Path(remote_dir),
                            bw_bytes_per_s=remote_bw,
                            request_latency_s=remote_latency_s)
    return TieredStore(fast, slow, remote=remote)
