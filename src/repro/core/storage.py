"""Two-tier checkpoint storage: burst buffer (fast, node-local) + scratch
(slow, shared) — the Cori DataWarp-vs-Lustre hierarchy from the paper's Fig 2.

On this box the "burst buffer" is /dev/shm (RAM-backed, real) and "scratch"
is disk behind a token-bucket bandwidth throttle, so the paper's measured
hierarchy (>20× checkpoint, ~2.5× restart) is reproducible deterministically.

Also implements the paper's P8: capacity preflight with a coded warning/error
instead of a mid-write failure.
"""
from __future__ import annotations

import os
import secrets
import shutil
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path

from .errors import SpaceError, warn


@dataclass
class Tier:
    name: str
    root: Path
    bw_bytes_per_s: float | None = None     # None = unthrottled
    capacity_bytes: int | None = None       # None = filesystem free space

    def __post_init__(self):
        self.root = Path(self.root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()
        self._bucket = 0.0
        self._last = time.monotonic()
        self._used = 0

    # --- capacity ---
    def free_bytes(self) -> int:
        if self.capacity_bytes is not None:
            return max(self.capacity_bytes - self._used, 0)
        st = os.statvfs(self.root)
        return st.f_bavail * st.f_frsize

    def preflight(self, required_bytes: int, *, headroom: float = 1.1):
        """Paper P8: warn at <2× requirement, fail below the requirement."""
        free = self.free_bytes()
        need = int(required_bytes * headroom)
        if free < need:
            raise SpaceError("insufficient space for checkpoint image",
                             tier=self.name, free=free, required=need)
        if free < 2 * need:
            warn("CKPT_W_SPACE", "checkpoint space headroom below 2x",
                 tier=self.name, free=free, required=need)

    # --- throttled IO ---
    def _throttle(self, nbytes: int):
        if not self.bw_bytes_per_s:
            return
        with self._lock:
            now = time.monotonic()
            self._bucket = min(self._bucket + (now - self._last)
                               * self.bw_bytes_per_s, self.bw_bytes_per_s)
            self._last = now
            self._bucket -= nbytes
            deficit = -self._bucket
        if deficit > 0:
            time.sleep(deficit / self.bw_bytes_per_s)

    def write_file(self, rel: str, data: bytes, *, atomic: bool = False):
        """`atomic=True` writes through a tmp name + rename so a torn write
        can never be mistaken for a complete file (drain copies use this —
        readers trust slow-tier files by existence)."""
        path = self.root / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        dst = path.with_name(
            path.name + f".tmp-{secrets.token_hex(4)}") if atomic else path
        chunk = 4 << 20
        with open(dst, "wb") as f:
            for i in range(0, len(data), chunk):
                piece = data[i:i + chunk]
                self._throttle(len(piece))
                f.write(piece)
            f.flush()
            os.fsync(f.fileno())
        if atomic:
            os.rename(dst, path)
        with self._lock:
            self._used += len(data)
        return path

    def read_file(self, rel: str) -> bytes:
        path = self.root / rel
        data = path.read_bytes()
        self._throttle(len(data))
        return data

    def read_into(self, rel: str, dest: memoryview) -> bool:
        """Direct-placement read: fill `dest` from the file without an
        intermediate bytes object. True iff the file length matched the
        destination exactly (a mismatch — truncated or over-long object —
        leaves the caller to fall back to the verified copy path)."""
        path = self.root / rel
        with open(path, "rb") as f:
            n = f.readinto(dest)
            ok = n == len(dest) and not f.read(1)
        self._throttle(n or 0)
        return ok

    def delete_file(self, rel: str) -> int:
        """Remove a file, returning the bytes freed (0 if absent)."""
        path = self.root / rel
        try:
            nbytes = path.stat().st_size
            path.unlink()
        except FileNotFoundError:
            return 0
        with self._lock:
            self._used = max(self._used - nbytes, 0)
        return nbytes


class TieredStore:
    """Writes land on the fast tier; committed checkpoints drain to the slow
    tier in the background (real burst-buffer semantics). Reads prefer fast.
    """

    def __init__(self, fast: Tier, slow: Tier | None = None,
                 drain_async: bool = True, io_executor=None):
        self.fast = fast
        self.slow = slow
        self.drain_async = drain_async
        # optional ChunkIOExecutor: drain copies fan out over it so the
        # read side (fast tier) overlaps the throttled write side (slow
        # tier). CheckpointManager shares its chunk pool here.
        self.io_executor = io_executor
        self._drainer: threading.Thread | None = None
        self._drain_err = None

    @property
    def root(self) -> Path:
        return self.fast.root

    def apply_pipeline_policy(self, pipeline) -> "TieredStore":
        """Adopt a ``PipelinePolicy``'s drain mode. ``async_drain=None``
        (the default) leaves the store as constructed — the policy only
        overrides what it explicitly sets, so a store built with
        ``drain_async=False`` isn't silently flipped by a default
        policy."""
        if getattr(pipeline, "async_drain", None) is not None:
            self.drain_async = bool(pipeline.async_drain)
        return self

    def tiers(self):
        return [t for t in (self.fast, self.slow) if t is not None]

    def drain_step(self, step_dir_name: str, extra_files=()):
        """Copy a committed checkpoint dir fast→slow (throttled) on ONE
        background thread, preceded by `extra_files` (CAS chunk objects
        live outside step directories). All copies are atomic writes, so a
        killed drain never leaves a torn file under a trusted name."""
        if self.slow is None:
            return
        src = self.fast.root / step_dir_name
        rels = [r for r in extra_files if (self.fast.root / r).is_file()]

        def _copy_extra(rel):
            f = self.fast.root / rel
            if f.is_file() and not (self.slow.root / rel).exists():
                self.slow.write_file(rel, f.read_bytes(), atomic=True)

        def _copy_step(p):
            rel = str(Path(step_dir_name) / p.relative_to(src))
            self.slow.write_file(rel, p.read_bytes(), atomic=True)

        def _copy():
            try:
                # a drain killed mid-write leaves .tmp- litter in slow-tier
                # step dirs that nothing else walks (gc_staging covers the
                # fast root, the CAS sweep covers _CAS) — purge it here,
                # off the save path; drains are serialized so no live tmp
                # file can be hit
                for t in self.slow.root.glob("step_*/**/*.tmp-*"):
                    try:
                        t.unlink()
                    except OSError:
                        pass
                step_files = [p for p in sorted(src.rglob("*"))
                              if p.is_file()]
                ex = self.io_executor
                if ex is not None and not ex.serial:
                    # two batches with a barrier between them: CAS objects
                    # must be fully landed before the step dir (and its
                    # manifest) can reference them on the slow tier
                    ex.map_ordered(_copy_extra, rels)
                    ex.map_ordered(_copy_step, step_files)
                else:
                    for rel in rels:
                        _copy_extra(rel)
                    for p in step_files:
                        _copy_step(p)
            except Exception as e:  # noqa
                self._drain_err = e

        if self.drain_async:
            self.wait_drained()
            self._drainer = threading.Thread(target=_copy, daemon=True)
            self._drainer.start()
        else:
            _copy()

    def wait_drained(self):
        if self._drainer is not None:
            self._drainer.join()
            self._drainer = None
        if self._drain_err is not None:
            e, self._drain_err = self._drain_err, None
            raise e

    def locate(self, rel: str) -> Tier | None:
        for t in self.tiers():
            if (t.root / rel).exists():
                return t
        return None

    def evict_fast(self, step_dir_name: str):
        """Free burst-buffer space once a step is safely on the slow tier."""
        if self.slow is None:
            return
        self.wait_drained()
        shutil.rmtree(self.fast.root / step_dir_name, ignore_errors=True)


def default_store(workdir: str | Path, *, burst_buffer: bool = True,
                  lustre_bw: float | None = 500e6) -> TieredStore:
    """fast = /dev/shm (if available), slow = <workdir>/scratch (throttled)."""
    workdir = Path(workdir)
    shm = Path("/dev/shm")
    if burst_buffer and shm.exists() and os.access(shm, os.W_OK):
        fast = Tier("burst-buffer", shm / f"repro-bb-{os.getpid()}" /
                    workdir.name)
    else:
        fast = Tier("local", workdir / "bb")
    slow = Tier("scratch-sim", workdir / "scratch", bw_bytes_per_s=lustre_bw)
    return TieredStore(fast, slow)
