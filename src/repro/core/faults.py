"""Deterministic storage fault injection — the IO counterpart of the
crash matrix (``atomic.CrashInjector``).

The crash injector kills the PROCESS at named points; this plane makes
the STORAGE misbehave underneath a process that keeps running — the
failure modes the paper's production runs actually hit (flaky Lustre
reads, a filling DataWarp allocation, bit-rot between write and
restart) and that a fail-fast stack turns into aborted rounds.

Every fault site is addressable by ``(op, tier, match, nth)``:

  * ``op``     — ``"read"`` (any read entry: ``read_file`` or
    ``read_into``), ``"read_file"`` / ``"read_into"`` (that entry only),
    ``"write"`` (``write_file``, including the CAS tmp writes), or
    ``"free"`` (``free_bytes`` — capacity preflight);
  * ``tier``   — tier name, or ``"*"`` for any tier;
  * ``match``  — substring of the storage-relative path (``""`` = all);
  * ``nth``    — the 1-based index of the matching call that fires, and
    ``count`` how many consecutive matching calls keep firing
    (``count=-1`` = every one from `nth` on — a persistent fault).

A ``FaultPlane`` holds the schedule and a seeded RNG (bit-rot offsets,
randomized schedules), so every failure a test or bench observes is
replayable from ``(seed, schedule)`` alone. ``FaultyTier`` wraps a
``storage.Tier`` and applies the schedule; ``wrap_store`` wraps every
tier of a ``TieredStore`` in place.

Fault kinds:

  ``eio``          read/write raises ``OSError(EIO)`` (``read_into``
                   honours the Tier contract: counts + returns False)
  ``enospc``       write raises ``OSError(ENOSPC)`` (transient when
                   ``count`` is small, a full tier when persistent)
  ``erofs``        write raises ``OSError(EROFS)`` — tier went read-only
  ``short_write``  a truncated prefix lands, THEN the write errors —
                   the torn write a caller can see and retry
  ``torn_write``   a truncated prefix lands silently — the torn write
                   only a scrub or an end-to-end check can see
  ``bitrot``       write: the file is written fully, then ONE byte (at a
                   seeded offset) is flipped on disk; read: the returned
                   bytes are flipped in memory (transient corruption)
  ``latency``      the op sleeps ``latency_s`` first, then proceeds
  ``vanish``       read: the file is deleted before the read proceeds
  ``full``         ``free_bytes`` reports 0 (capacity preflight fails)
  ``truncated_get``  remote ranged GET (``read_range``) returns short: a
                   prefix of the part lands, then False — the multipart
                   truncation an S3 connection reset produces
  ``stale_head``   remote HEAD/GET disagreement: ``read_into`` sees a
                   size mismatch (counts + False), ``read_file`` raises
                   the typed transient ``RemoteInconsistencyError`` —
                   read-after-overwrite staleness
"""
from __future__ import annotations

import errno
import os
import random
import threading
import time
from dataclasses import dataclass, field

FAULT_KINDS = ("eio", "enospc", "erofs", "short_write", "torn_write",
               "bitrot", "latency", "vanish", "full",
               "truncated_get", "stale_head")
READ_OPS = ("read", "read_file", "read_into")
# read_range is NOT folded under the "read" alias: a multipart GET would
# advance a generic read spec's match counter once per PART, silently
# reshaping every existing schedule — per-part faults are addressed
# explicitly by op="read_range"
_OPS = ("read", "read_file", "read_into", "read_range", "write", "free")


@dataclass
class FaultSpec:
    """One addressable fault site. Mutable on purpose: the plane tracks
    per-spec match/fire counts here so a schedule is also its own
    replay log."""
    op: str
    kind: str
    tier: str = "*"
    match: str = ""
    nth: int = 1
    count: int = 1              # consecutive firings; -1 = persistent
    latency_s: float = 0.005
    matched: int = field(default=0, repr=False)
    fired: int = field(default=0, repr=False)

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"op must be one of {_OPS}, got {self.op!r}")
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"kind must be one of {FAULT_KINDS}, "
                             f"got {self.kind!r}")
        if int(self.nth) < 1:
            raise ValueError("nth is 1-based and must be >= 1")

    @property
    def key(self) -> tuple:
        return (self.op, self.tier, self.match, self.nth)


class FaultPlane:
    """A seeded, replayable fault schedule shared by every ``FaultyTier``
    of a store. Thread-safe: writer ranks and pool workers poll it
    concurrently."""

    def __init__(self, specs=(), seed: int = 0):
        self.seed = int(seed)
        self.rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self.specs: list[FaultSpec] = [
            s if isinstance(s, FaultSpec) else FaultSpec(**s)
            for s in specs]
        self.log: list[tuple] = []      # (op, tier, rel, kind, key)

    def add(self, op: str, kind: str, **kw) -> FaultSpec:
        spec = FaultSpec(op=op, kind=kind, **kw)
        with self._lock:
            self.specs.append(spec)
        return spec

    def clear(self):
        with self._lock:
            self.specs.clear()

    def poll(self, ops, tier: str, rel: str) -> FaultSpec | None:
        """Advance match counters for every spec matching ``(ops, tier,
        rel)`` and return the first spec inside its firing window. One
        call = one matched IO, whichever op alias it arrives under."""
        if isinstance(ops, str):
            ops = (ops,)
        hit = None
        with self._lock:
            for spec in self.specs:
                if spec.op not in ops:
                    continue
                if spec.tier != "*" and spec.tier != tier:
                    continue
                if spec.match and spec.match not in rel:
                    continue
                spec.matched += 1
                if hit is not None:
                    continue        # counters advance; first hit wins
                past = spec.matched - spec.nth
                if past >= 0 and (spec.count < 0 or past < spec.count):
                    spec.fired += 1
                    hit = spec
                    self.log.append((spec.op, tier, rel, spec.kind,
                                     spec.key))
        return hit

    def fired(self) -> list:
        with self._lock:
            return list(self.log)

    def rot_offset(self, n: int) -> int:
        """Seeded byte offset for a bit-rot fault over an n-byte file."""
        with self._lock:
            return self.rng.randrange(n) if n > 0 else 0

    # a catalog of recoverable fault shapes for randomized chaos runs:
    # every entry targets the fast tier with a bounded window, so a store
    # with a slow tier (and the pipelined engine's retries) must always
    # converge to a bit-exact restore
    RANDOM_CATALOG = (
        ("write", "eio"), ("write", "enospc"), ("write", "short_write"),
        ("write", "latency"), ("read", "eio"), ("read", "short_write"),
        ("read", "bitrot"), ("read", "latency"), ("read", "vanish"),
    )

    @classmethod
    def random_schedule(cls, seed: int, n: int = 4,
                        tier: str = "fast", match: str = ".obj") \
            -> "FaultPlane":
        """A deterministic function of `seed`: `n` transient faults drawn
        from ``RANDOM_CATALOG`` at randomized positions — the chaos-smoke
        schedule. Replaying the seed replays the exact schedule."""
        rng = random.Random(int(seed))
        plane = cls(seed=int(seed))
        for _ in range(max(int(n), 1)):
            op, kind = rng.choice(cls.RANDOM_CATALOG)
            if op == "read" and kind == "short_write":
                kind = "eio"        # short_write is a write-side kind
            plane.add(op, kind, tier=tier, match=match,
                      nth=rng.randint(1, 12), count=rng.randint(1, 2),
                      latency_s=0.002)
        return plane


def _eio(rel):
    return OSError(errno.EIO, "injected EIO", rel)


class FaultyTier:
    """Transparent fault-applying wrapper around a ``storage.Tier`` (or
    ``RemoteTier``). Everything not overridden delegates to the wrapped
    tier — including attribute writes, so policy adoption
    (``store.remote.part_bytes = ...``) keeps working. Composes under
    ``TieredStore`` by identity: ``wrap_store`` REPLACES ``store.fast``/
    ``slow``/``remote``, so ``tier is store.fast`` checks hold."""

    def __init__(self, tier, plane: FaultPlane):
        self.__dict__["_inner"] = tier
        self.__dict__["_plane"] = plane

    @property
    def inner(self):
        return self._inner

    def __getattr__(self, name):
        return getattr(self.__dict__["_inner"], name)

    def __setattr__(self, name, value):
        setattr(self.__dict__["_inner"], name, value)

    # --- faulted ops ---------------------------------------------------
    def free_bytes(self) -> int:
        spec = self._plane.poll("free", self._inner.name, "")
        if spec is not None and spec.kind == "full":
            return 0
        return self._inner.free_bytes()

    def preflight(self, required_bytes: int, *, headroom: float = 1.1):
        # re-dispatch through the WRAPPER's free_bytes (the inner bound
        # method would bypass the "full" fault)
        from .storage import Tier
        return Tier.preflight(self, required_bytes, headroom=headroom)

    def write_file(self, rel: str, data: bytes, *, atomic: bool = False):
        inner = self._inner
        spec = self._plane.poll("write", inner.name, rel)
        if spec is None:
            return inner.write_file(rel, data, atomic=atomic)
        k = spec.kind
        if k == "latency":
            time.sleep(spec.latency_s)
            return inner.write_file(rel, data, atomic=atomic)
        if k == "eio":
            raise _eio(rel)
        if k == "enospc":
            raise OSError(errno.ENOSPC, "injected ENOSPC", rel)
        if k == "erofs":
            raise OSError(errno.EROFS, "injected EROFS", rel)
        if k == "short_write":
            # a visible torn write: half the bytes land, then the error
            inner.write_file(rel, bytes(data[:len(data) // 2]))
            raise _eio(rel)
        if k == "torn_write":
            # a SILENT torn write under the final name — only a scrub or
            # an end-to-end integrity check can see it
            return inner.write_file(rel, bytes(data[:len(data) // 2]))
        if k == "bitrot":
            path = inner.write_file(rel, data, atomic=atomic)
            self._flip_byte_on_disk(path)
            return path
        # enospc-family kinds that only make sense elsewhere fall through
        return inner.write_file(rel, data, atomic=atomic)

    def read_file(self, rel: str) -> bytes:
        inner = self._inner
        spec = self._plane.poll(("read_file", "read"), inner.name, rel)
        if spec is None:
            return inner.read_file(rel)
        k = spec.kind
        if k == "latency":
            time.sleep(spec.latency_s)
            return inner.read_file(rel)
        if k == "eio":
            raise _eio(rel)
        if k == "vanish":
            inner.delete_file(rel)
            return inner.read_file(rel)     # raises FileNotFoundError
        if k == "stale_head":
            from .resilience import RemoteInconsistencyError
            inner._note_read_failure(rel, "injected stale HEAD",
                                     "stale_head")
            raise RemoteInconsistencyError(
                f"injected stale HEAD: {rel}", rel=rel, kind="stale_head")
        data = inner.read_file(rel)
        if k in ("short_write", "torn_write", "truncated_get"):
            return data[:len(data) // 2]    # short READ: truncated bytes
        if k == "bitrot" and data:
            buf = bytearray(data)
            buf[self._plane.rot_offset(len(buf))] ^= 0x01
            return bytes(buf)
        return data

    def _inner_read_into(self, rel: str, dest) -> bool:
        # a remote inner tier re-dispatches through the WRAPPER's
        # ``read_range`` (same trick as ``preflight``): each part of the
        # multipart GET polls the plane, so op="read_range" faults
        # (truncated_get) actually fire mid-object
        if hasattr(self._inner, "read_range"):
            from .storage import RemoteTier
            return RemoteTier.read_into(self, rel, dest)
        return self._inner.read_into(rel, dest)

    def read_range(self, rel: str, dest, offset: int) -> bool:
        inner = self._inner
        spec = self._plane.poll("read_range", inner.name, rel)
        if spec is None:
            return inner.read_range(rel, dest, offset)
        k = spec.kind
        if k == "latency":
            time.sleep(spec.latency_s)
            return inner.read_range(rel, dest, offset)
        if k == "eio":
            inner._note_read_failure(rel, "injected EIO", "read_error")
            return False
        if k == "truncated_get":
            # the part's prefix actually lands in dest, then the GET
            # "connection" dies — short bytes visible, length honest
            half = memoryview(dest)[:len(dest) // 2]
            if len(half):
                inner.read_range(rel, half, offset)
            inner._note_read_failure(
                rel, f"injected truncated GET at offset {offset}",
                "truncated_get")
            return False
        if k == "vanish":
            inner.delete_file(rel)
            return inner.read_range(rel, dest, offset)
        return inner.read_range(rel, dest, offset)

    def read_into(self, rel: str, dest) -> bool:
        inner = self._inner
        spec = self._plane.poll(("read_into", "read"), inner.name, rel)
        if spec is None:
            return self._inner_read_into(rel, dest)
        k = spec.kind
        if k == "latency":
            time.sleep(spec.latency_s)
            return self._inner_read_into(rel, dest)
        if k == "eio":
            # honour the Tier contract (False, never raise) but keep the
            # failure VISIBLE through the same counters/logging a real
            # EIO inside read_into would hit
            inner._note_read_failure(rel, "injected EIO", "read_error")
            return False
        if k == "stale_head":
            # HEAD advertised one size, the object is another: detected
            # before any part is fetched — counts + False, never raises
            inner._note_read_failure(rel, "injected stale HEAD",
                                     "stale_head")
            return False
        if k == "vanish":
            inner.delete_file(rel)
            return self._inner_read_into(rel, dest)
        ok = self._inner_read_into(rel, dest)
        if not ok:
            return False
        if k in ("short_write", "torn_write"):
            inner._note_read_failure(rel, "injected short read",
                                     "short_read")
            return False                    # bytes landed, length "lied"
        if k == "bitrot" and len(dest):
            dest[self._plane.rot_offset(len(dest))] ^= 0x01
        return ok

    def _flip_byte_on_disk(self, path):
        try:
            size = os.path.getsize(path)
            if size <= 0:
                return
            off = self._plane.rot_offset(size)
            with open(path, "r+b") as f:
                f.seek(off)
                b = f.read(1)
                f.seek(off)
                f.write(bytes([b[0] ^ 0x01]))
                f.flush()
                os.fsync(f.fileno())
        except OSError:
            pass


def wrap_store(store, plane: FaultPlane):
    """Wrap every mounted tier of `store` in a ``FaultyTier`` sharing
    `plane`, in place. Returns `store` for chaining."""
    for name in ("fast", "slow", "remote"):
        tier = getattr(store, name, None)
        if tier is not None and not isinstance(tier, FaultyTier):
            setattr(store, name, FaultyTier(tier, plane))
    peers = getattr(store, "peers", None)
    if peers:
        store.peers = [p if isinstance(p, FaultyTier)
                       else FaultyTier(p, plane) for p in peers]
    return store
