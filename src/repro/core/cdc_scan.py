"""Batched gear-scan engine — the accelerated candidate scan under CDC.

Content-defined chunking is only "free" at save time when the rolling-hash
scan runs near memory bandwidth. The PR-2 scan is a vectorized numpy
pipeline (gather → cumsum → windowed diff → mask → nonzero); every stage
materializes a full-payload temporary, so on a bandwidth-starved host it
tops out well below the hash/write pipeline it feeds (~50 MB/s on the
reference box — the ROADMAP's CDC-throughput item). This module keeps that
numpy implementation as the *correctness oracle* and adds two accelerated
backends that compute byte-identical candidates:

  numpy    the PR-2 scan, unchanged — the oracle every other backend is
           property-tested against (cut points are the dedup keyspace:
           a backend that drifts by one byte re-writes history);

  jnp      an XLA pipeline built for exactness AND cache locality: the
           payload is cut into ~4 MiB segments (64-byte halo carries the
           rolling-window context across the cut, so segmentation is
           exact); each segment is laid out as columns of ``BLOCK`` bytes
           and scanned with ONE ``lax.scan`` whose per-step state is a
           single row of window sums — w[i] = w[i-1] + gear[enter] -
           gear[leave] — all fused by XLA into a sliding pass whose
           working set lives in cache. The device emits a per-position
           candidate byte (0 / loose / strict) plus a per-64-block hit
           bitmap, and the host only inspects blocks the bitmap flags
           (candidates are geometrically rare, so extraction is ~free).
           Measured on the 2-core reference box: 5-7× the numpy oracle at
           shard-sized payloads — and the same dispatch is async, so a
           SaveSession overlaps the scan of payload k+1 with the chunk
           hash/write of payload k;

  pallas   the same blocked scan as an explicit accelerator kernel (one
           grid program per block, halo block passed alongside) for
           GPU/TPU hosts, where the gather+cumsum runs at HBM bandwidth.
           On hosts without an accelerator it falls back to ``jnp`` (a
           one-time warning); correctness is pinned by interpret-mode
           parity tests.

Backend choice is a knob (``GearChunker(scan_backend=...)``), with
``auto`` picking pallas on accelerator hosts, jnp for payloads large
enough to amortize a dispatch, and numpy below that.
"""
from __future__ import annotations

import hashlib
import threading

import numpy as np

from . import codec as codec_mod
from .errors import warn

WINDOW = 64          # rolling-hash window (bytes); boundaries depend on
                     # exactly this much trailing context
BLOCK = 1024         # jnp scan column height (positions per lax.scan step
                     # stride); chosen on the reference box sweep
SEGMENT_BYTES = 4 << 20      # per-dispatch span: large enough to amortize
                             # dispatch, small enough to stay cache-warm
MIN_ACCEL_BYTES = 2 << 20    # auto: below this the numpy oracle wins
                             # (dispatch + padding overhead)
_MIN_COLS = 16               # smallest tail bucket: 16 columns = 16 KiB
BACKENDS = ("auto", "numpy", "jnp", "pallas")


def _bucket_cols(cols: int) -> int:
    """Half-octave staging bucket for ``cols`` scan columns: sizes step
    …16, 24, 32, 48, 64… so the padded dispatch wastes ≤33% instead of
    the ≤100% a pure power-of-two ladder costs — the chunk-scan
    small-payload gap (sub-2 MiB payloads paying full padding overhead).
    Still two shapes per octave, so the per-shape jit cache and the
    staging arena stay bounded."""
    b = _MIN_COLS
    while b < cols:
        half = b + (b >> 1)
        if cols <= half:
            return half
        b *= 2
    return b


def _gear_table() -> np.ndarray:
    # uint32, not uint64: the scan is memory-bandwidth bound and no mask
    # ever needs more than 32 bits (avg_size is capped at 2^28)
    out = np.empty(256, np.uint32)
    for b in range(256):
        h = hashlib.blake2b(bytes([b]), digest_size=4,
                            person=b"repro-cdc-v1").digest()
        out[b] = int.from_bytes(h, "little")
    return out


GEAR = _gear_table()

_EMPTY = np.empty(0, np.int64)


def as_u8(payload) -> np.ndarray:
    """Zero-copy uint8 view of any buffer the save path feeds the chunker
    (bytes, memoryview, contiguous ndarray)."""
    if isinstance(payload, np.ndarray):
        return payload.reshape(-1).view(np.uint8)
    return np.frombuffer(payload, np.uint8)


# ---------------------------------------------------------------------------
# numpy backend — the correctness oracle
# ---------------------------------------------------------------------------

def scan_candidates_numpy(data: np.ndarray, mask_strict: int,
                          mask_loose: int):
    """All candidate cut *end offsets* (strict set, loose set) — the PR-2
    scan, byte for byte. Every accelerated backend is tested against this.
    """
    n = len(data)
    if n <= WINDOW:
        return _EMPTY, _EMPTY
    v = GEAR[data]
    c = np.cumsum(v, dtype=np.uint32)          # wraps mod 2^32 — intended
    # window sum ending at byte i (inclusive), for i in [WINDOW-1, n-1]
    s = c[WINDOW - 1:].copy()
    s[1:] -= c[:n - WINDOW]
    loose = np.nonzero((s & np.uint32(mask_loose)) == 0)[0] + WINDOW
    strict = loose[(s[loose - WINDOW] & np.uint32(mask_strict)) == 0]
    return strict.astype(np.int64), loose.astype(np.int64)


# ---------------------------------------------------------------------------
# jnp backend — segmented sliding-window lax.scan
# ---------------------------------------------------------------------------

def _scan_columns_expr(padded, mask_strict, mask_loose):
    """Traceable column gear scan — the shared body of the jitted segment
    scan AND the fused transform+scan dispatch.

    ``padded``: uint8 [WINDOW + nb*BLOCK] — WINDOW halo bytes (previous
    segment's tail, zeros/garbage for the payload head: every halo byte
    entering w0 is subtracted back out of the sliding-window algebra
    before the first valid position), then the span, padded up to a
    column bucket (tail positions are discarded by extraction)."""
    import jax
    import jax.numpy as jnp

    nb = (padded.shape[0] - WINDOW) // BLOCK
    gear = jnp.asarray(GEAR)
    # column layout: column b holds payload positions [b*BLOCK,
    # (b+1)*BLOCK); the scan step advances every column's sliding
    # window by one byte, so the whole per-step state is one row
    main = padded[WINDOW:].reshape(nb, BLOCK).T     # entering bytes
    lead = padded[:-WINDOW].reshape(nb, BLOCK).T    # leaving bytes
    halo = padded[:-WINDOW].reshape(nb, BLOCK)[:, :WINDOW].T
    w0 = jnp.sum(gear[halo], axis=0, dtype=jnp.uint32)

    ms = jnp.uint32(mask_strict)
    ml = jnp.uint32(mask_loose)

    def body(w, rows):
        enter, leave = rows
        w = w + gear[enter] - gear[leave]
        # loose mask bits ⊂ strict mask bits, so one AND serves both
        h = w & ms
        m = ((h & ml) == 0).astype(jnp.uint8) \
            + (h == 0).astype(jnp.uint8)
        return w, m

    _, out = jax.lax.scan(body, w0, (main, lead))   # [BLOCK, nb]
    # per-64-block hit bitmap: the host only reads blocks that hit
    flags = out.reshape(BLOCK // WINDOW, WINDOW, nb).max(axis=1)
    return out, flags


def _jnp_scan_fn():
    """Build (once) the jitted segment scan. Static args: the two masks —
    jax caches one executable per (padded length, mask pair). The input
    is donated where donation is real (accelerators free the device copy
    as soon as the scan consumes it); on CPU donation would only warn."""
    import jax

    donate = (0,) if accelerator_present() else ()
    return jax.jit(_scan_columns_expr, static_argnums=(1, 2),
                   donate_argnums=donate)


class _StagingArena:
    """Persistent staging-buffer pool for accelerated dispatches.

    ``jnp.asarray`` on CPU may zero-copy ALIAS an aligned numpy buffer
    instead of copying it (measured both behaviours on this box), so a
    staging buffer must NEVER be reused while its dispatch is in flight —
    that is the documented no-reuse rule. The arena honours it by
    recycling a buffer only after its dispatch has been extracted (the
    device outputs are materialized, so the executable that could read
    the alias has provably finished); the device-side copy is donated to
    the jit on accelerator hosts instead. Recycling is what closes the
    chunk-scan small-payload gap: a 2 MiB dispatch stops paying the
    fresh-allocation page-zeroing that dominated its fixed overhead."""

    MAX_PER_SIZE = 4    # idle buffers kept per size (≥ in-flight window)

    def __init__(self):
        self._lock = threading.Lock()
        self._free: dict = {}           # nbytes → [np.ndarray]

    def acquire(self, n: int) -> np.ndarray:
        with self._lock:
            bufs = self._free.get(n)
            if bufs:
                return bufs.pop()
        return np.empty(n, np.uint8)

    def release(self, buf):
        if buf is None:
            return
        with self._lock:
            bufs = self._free.setdefault(buf.nbytes, [])
            if len(bufs) < self.MAX_PER_SIZE:
                bufs.append(buf)


_ARENA = _StagingArena()


def _staging(n: int) -> np.ndarray:
    """Staging buffer for one dispatch: recycled from the arena when a
    previously-extracted dispatch's buffer fits, fresh otherwise. Never
    handed out while in flight (see ``_StagingArena``)."""
    return _ARENA.acquire(n)


class _JnpBackend:
    """Per-process jnp scan state (lazily built; thread-safe — jax.jit
    executables are shareable across threads)."""

    _lock = threading.Lock()
    _fn = None

    @classmethod
    def fn(cls):
        with cls._lock:
            if cls._fn is None:
                cls._fn = _jnp_scan_fn()
            return cls._fn

    @staticmethod
    def dispatch(data: np.ndarray, start: int, seg_len: int,
                 mask_strict: int, mask_loose: int):
        """Launch one segment scan (async — jax returns before the device
        finishes). Returns (device result pair, staging buffer) — the
        caller releases the buffer to the arena once it extracts.

        Staging never zeroes: garbage in the halo head and the bucket
        tail is EXACT to leave there. Halo garbage cancels out of the
        sliding-window algebra after WINDOW steps (every halo byte
        entering w0 is subtracted as a leaving byte before the first
        valid position), and tail positions beyond ``seg_len`` are
        discarded by extraction — so the scan pays one warm memcpy and
        zero page-zeroing."""
        import jax.numpy as jnp
        cols = -(-seg_len // BLOCK)
        # half-octave tail buckets keep recompilation bounded (full
        # segments all share one shape) without doubling small dispatches
        bucket = _bucket_cols(cols)
        padded = _staging(WINDOW + bucket * BLOCK)
        halo = min(start, WINDOW)
        if halo:
            padded[WINDOW - halo:WINDOW] = data[start - halo:start]
        padded[WINDOW:WINDOW + seg_len] = data[start:start + seg_len]
        return _JnpBackend.fn()(jnp.asarray(padded), int(mask_strict),
                                int(mask_loose)), padded

    @staticmethod
    def extract(result, start: int, seg_len: int, total_len: int):
        """Device result → global candidate positions of one segment.
        Only flagged 64-blocks are inspected; positions below the first
        full window (global < WINDOW-1) and in the zero-pad tail are
        discarded — they match the oracle's validity range."""
        out, flags = result
        flags_np = np.asarray(flags)                   # [BLOCK/W, cols]
        bs, qs = np.nonzero(flags_np.T)                # sorted by position
        if not len(bs):
            return _EMPTY, _EMPTY
        out_np = np.asarray(out)                       # [BLOCK, cols]
        blocks = out_np.reshape(BLOCK // WINDOW, WINDOW, -1)
        m = blocks[qs, :, bs]                          # [hits, WINDOW]
        base = (bs.astype(np.int64) * BLOCK + qs * WINDOW)[:, None]
        pos = base + np.arange(WINDOW, dtype=np.int64)
        sel = m > 0
        p, mv = pos[sel], m[sel]                       # row-major: sorted
        gp = p + start
        ok = (p < seg_len) & (gp >= WINDOW - 1) & (gp < total_len)
        gp, mv = gp[ok], mv[ok]
        return (gp[mv == 2] + 1), (gp + 1)


# ---------------------------------------------------------------------------
# pallas backend — explicit accelerator kernel (GPU/TPU), jnp fallback
# ---------------------------------------------------------------------------

PALLAS_BLOCK = 64 << 10      # bytes per grid program


def _pallas_scan_expr(padded, mask_strict, mask_loose, *,
                      interpret: bool = False):
    """Traceable blocked gear scan as a Pallas kernel: one grid program
    per ``PALLAS_BLOCK`` span, with the *previous* block passed as a
    second input so each program sees its 64-byte halo (program 0 reads
    itself; its halo region falls below the first full window and is
    discarded by extraction). Emits the same 0/loose/strict mask byte per
    position as the jnp backend. Shared by the jitted segment scan and
    the fused transform+scan dispatch."""
    import functools

    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(gear_ref, halo_ref, main_ref, out_ref, *, mask_strict,
               mask_loose):
        buf = jnp.concatenate([halo_ref[-WINDOW:], main_ref[...]])
        g = jnp.take(gear_ref[...], buf.astype(jnp.int32))
        c = jnp.cumsum(g, dtype=jnp.uint32)            # wraps mod 2^32
        w = c[WINDOW:] - c[:-WINDOW]
        h = w & jnp.uint32(mask_strict)
        out_ref[...] = ((h & jnp.uint32(mask_loose)) == 0) \
            .astype(jnp.uint8) + (h == 0).astype(jnp.uint8)

    n = padded.shape[0]
    grid = (n // PALLAS_BLOCK,)
    return pl.pallas_call(
        functools.partial(kernel, mask_strict=mask_strict,
                          mask_loose=mask_loose),
        grid=grid,
        in_specs=[
            pl.BlockSpec((256,), lambda i: (0,)),          # gear table
            pl.BlockSpec((PALLAS_BLOCK,),
                         lambda i: (jnp.maximum(i - 1, 0),)),  # halo
            pl.BlockSpec((PALLAS_BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((PALLAS_BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint8),
        interpret=interpret,
    )(jnp.asarray(GEAR), padded, padded)


def _pallas_scan_fn(interpret: bool = False):
    import jax

    def scan(padded, mask_strict, mask_loose):
        return _pallas_scan_expr(padded, mask_strict, mask_loose,
                                 interpret=interpret)

    donate = (0,) if accelerator_present() else ()
    return jax.jit(scan, static_argnums=(1, 2), donate_argnums=donate)


class _PallasBackend:
    """Pallas dispatch; one mask-byte array per segment, extracted on the
    host with a plain nonzero (accelerator hosts are not the ones starved
    for host cycles)."""

    def __init__(self, interpret: bool = False):
        self._fn = _pallas_scan_fn(interpret=interpret)

    def dispatch(self, data: np.ndarray, start: int, seg_len: int,
                 mask_strict: int, mask_loose: int):
        import jax.numpy as jnp
        # WINDOW halo bytes ahead of the segment carry the rolling-window
        # context across the segment cut (program 0 of the grid reads its
        # own block as halo; those positions land in the discarded region
        # below)
        padded_len = -(-(seg_len + WINDOW) // PALLAS_BLOCK) * PALLAS_BLOCK
        # warm staging, never zeroed: halo/tail garbage is filtered by
        # extraction (and the first-window positions it could influence
        # are below WINDOW-1)
        padded = _staging(padded_len)
        halo = min(start, WINDOW)
        if halo:
            padded[WINDOW - halo:WINDOW] = data[start - halo:start]
        padded[WINDOW:WINDOW + seg_len] = data[start:start + seg_len]
        return self._fn(jnp.asarray(padded), int(mask_strict),
                        int(mask_loose)), padded

    @staticmethod
    def extract(result, start: int, seg_len: int, total_len: int):
        mask = np.asarray(result)
        p = np.flatnonzero(mask) - WINDOW        # → segment-local positions
        p = p[(p >= 0) & (p < seg_len)]
        gp = p + start
        ok = (gp >= WINDOW - 1) & (gp < total_len)
        gp = gp[ok]
        mv = mask[p + WINDOW][ok]
        return (gp[mv == 2] + 1), (gp + 1)


def accelerator_present() -> bool:
    try:
        import jax
        return jax.default_backend() in ("gpu", "tpu", "cuda", "rocm")
    except Exception:  # noqa — no usable jax: numpy oracle still works
        return False


# ---------------------------------------------------------------------------
# fused transform+scan — the byteplane codec's single device round-trip
# ---------------------------------------------------------------------------

_fused_lock = threading.Lock()
_fused_fns: dict = {}     # (backend, interpret, entropy) → jitted executable


def _build_fused_fn(backend: str, interpret: bool = False,
                    entropy: str | None = None):
    """Build the fused byteplane-forward + gear-scan executable: ONE
    device round-trip per payload returns the transformed bytes AND the
    candidate mask computed over them, so the byteplane codec costs no
    extra dispatch beyond the CDC scan the save queue already pays for.

    With ``entropy`` set (a chunk-encoded codec name) a THIRD stage runs
    in the same dispatch: the plane RLE/rANS block encoder over the
    transformed stream. The executable then returns the candidate mask
    plus the pre-compressed framed stream and its per-block lengths — the
    transformed bytes themselves never cross D2H, so the transfer and all
    downstream host hashing shrink to the encoded size.

    Whole-payload dispatch, unlike the segmented plain scan: the
    byteplane transform is a global permutation of the stream, so
    per-segment halos would not compose across it. jax caches one
    executable per payload length — a training job's shard shapes form a
    small fixed set, so recompilation is bounded in practice."""
    import jax
    import jax.numpy as jnp

    from ..kernels.ckpt_codec import byteplane as bp
    from ..kernels.ckpt_codec import entropy as ent

    if backend == "pallas":
        def impl(raw, itemsize, mask_strict, mask_loose):
            t = bp.forward_pallas_expr(raw, itemsize, interpret=interpret)
            n = raw.shape[0]
            padded_len = -(-(n + WINDOW) // PALLAS_BLOCK) * PALLAS_BLOCK
            padded = jnp.concatenate(
                [jnp.zeros(WINDOW, jnp.uint8), t,
                 jnp.zeros(padded_len - WINDOW - n, jnp.uint8)])
            scan = _pallas_scan_expr(padded, mask_strict, mask_loose,
                                     interpret=interpret)
            if entropy is None:
                return t, scan
            return (scan,) + ent.encode_pallas_expr(
                t, entropy, interpret=interpret)
    else:
        def impl(raw, itemsize, mask_strict, mask_loose):
            t = bp.forward_expr(raw, itemsize)
            n = raw.shape[0]
            bucket = _bucket_cols(-(-n // BLOCK))
            padded = jnp.concatenate(
                [jnp.zeros(WINDOW, jnp.uint8), t,
                 jnp.zeros(bucket * BLOCK - n, jnp.uint8)])
            scan = _scan_columns_expr(padded, mask_strict, mask_loose)
            if entropy is None:
                return (t,) + scan
            return scan + ent.encode_expr(t, entropy)

    donate = (0,) if accelerator_present() else ()
    return jax.jit(impl, static_argnums=(1, 2, 3), donate_argnums=donate)


def _fused_fn(backend: str, interpret: bool = False,
              entropy: str | None = None):
    key = (backend, interpret, entropy)
    with _fused_lock:
        fn = _fused_fns.get(key)
        if fn is None:
            fn = _fused_fns[key] = _build_fused_fn(backend, interpret,
                                                   entropy)
        return fn


class FusedScanTicket:
    """Handle for one fused byteplane-transform + candidate-scan
    dispatch. ``result()`` joins the device round-trip and returns
    ``((strict, loose), transformed)``: candidate end offsets computed
    OVER the transformed stream (byte-identical to the numpy oracle
    scanning the oracle transform — the transformed bytes are the dedup
    keyspace) plus the transformed payload as a host uint8 array."""

    __slots__ = ("_resolve", "_done")

    def __init__(self, resolve=None, done=None):
        self._resolve = resolve
        self._done = done

    def result(self):
        if self._done is None:
            self._done = self._resolve()
            self._resolve = None
        return self._done


class FusedEncodeTicket:
    """Handle for one fused transform + scan + plane-entropy dispatch.
    ``result()`` joins the device round-trip and returns
    ``((strict, loose), stream, block_lens)``: candidate end offsets over
    the transformed stream, the framed RLE/rANS block stream (host uint8,
    byte-identical to the oracle encoding of the oracle transform) and
    per-block encoded lengths (headers included) whose prefix sums let
    the save path slice any plane-block-aligned chunk's encoding out of
    the stream without re-encoding."""

    __slots__ = ("_resolve", "_done")

    def __init__(self, resolve=None, done=None):
        self._resolve = resolve
        self._done = done

    def result(self):
        if self._done is None:
            self._done = self._resolve()
            self._resolve = None
        return self._done


class TransformTicket:
    """Handle for one standalone async device byteplane transform (no
    candidate scan). ``result()`` returns the transformed stream as a
    host uint8 array, byte-identical to the oracle."""

    __slots__ = ("_dev", "_done")

    def __init__(self, dev=None, done=None):
        self._dev = dev
        self._done = done

    def result(self) -> np.ndarray:
        if self._done is None:
            self._done = np.asarray(self._dev)
            self._dev = None
        return self._done


def transform_async(payload, itemsize: int) -> TransformTicket:
    """Async byteplane forward transform WITHOUT a candidate scan — the
    save path uses this when the codec wants pre-conditioned bytes but
    the chunk grid is not content-defined over them (fixed chunking, or
    a replica feed). Below the acceleration threshold the host oracle
    runs inline — same bytes either way."""
    data = as_u8(payload)
    if len(data) < MIN_ACCEL_BYTES:
        return TransformTicket(
            done=codec_mod.byteplane_forward(data, itemsize))
    import jax.numpy as jnp

    from ..kernels.ckpt_codec import byteplane as bp
    if accelerator_present():
        dev = bp.forward_pallas(jnp.asarray(data), itemsize=int(itemsize))
    else:
        dev = bp.forward_jnp(jnp.asarray(data), itemsize=int(itemsize))
    return TransformTicket(dev=dev)


# ---------------------------------------------------------------------------
# the scanner
# ---------------------------------------------------------------------------

MAX_INFLIGHT_SEGMENTS = 3    # bounds live staging+result memory: a large
                             # payload scans as a pipeline of cache-sized
                             # segments, not one giant working set


class ScanTicket:
    """Handle for one (possibly in-flight) payload scan. ``result()``
    joins the device work and returns the (strict, loose) candidate end
    offsets — byte-identical to the numpy oracle.

    Dispatch is WINDOWED: the first ``MAX_INFLIGHT_SEGMENTS`` segments
    are launched by ``scan_async`` (so device work overlaps whatever the
    caller does next); the rest launch from ``result()`` as earlier
    segments extract, keeping at most a few segments of staging buffers
    and device results alive at once."""

    __slots__ = ("_pending", "_todo", "_dispatch", "_extract", "_done")

    def __init__(self, pending, todo, dispatch, extract, done=None):
        self._pending = pending         # deque of ((result, buf), start, len, n)
        self._todo = todo               # [(start, seg_len, total)] not yet launched
        self._dispatch = dispatch
        self._extract = extract
        self._done = done               # eager backends resolve immediately

    def result(self):
        if self._done is None:
            strict, loose = [], []
            while self._pending:
                (res, buf), start, seg_len, total = self._pending.popleft()
                s, l = self._extract(res, start, seg_len, total)
                # extraction materialized the device outputs, so the
                # dispatch that could alias this staging buffer is done —
                # the one point where recycling is provably safe
                _ARENA.release(buf)
                strict.append(s)
                loose.append(l)
                if self._todo:
                    nstart, nlen, ntotal = self._todo.pop(0)
                    self._pending.append(
                        (self._dispatch(nstart, nlen), nstart, nlen, ntotal))
            self._done = (
                np.concatenate(strict) if strict else _EMPTY,
                np.concatenate(loose) if loose else _EMPTY)
            self._pending = self._todo = self._dispatch = None
        return self._done


_pallas_warned = False


class GearScanner:
    """Candidate scan for one (mask_strict, mask_loose) pair with a
    selectable backend. ``scan`` is synchronous; ``scan_async`` dispatches
    device work and returns a ticket, which is how the save path overlaps
    the scan of the next payload with the chunk hash/write of the current
    one."""

    def __init__(self, mask_strict: int, mask_loose: int, *,
                 backend: str = "auto", pallas_interpret: bool = False):
        if backend not in BACKENDS:
            raise ValueError(f"scan_backend must be one of {BACKENDS}, "
                             f"got {backend!r}")
        self.mask_strict = int(mask_strict)
        self.mask_loose = int(mask_loose)
        if self.mask_loose & ~self.mask_strict:
            # the single-AND trick in the accelerated backends (and the
            # strict-⊆-loose candidate algebra) both require nested masks
            raise ValueError("mask_loose must be a bit-subset of "
                             "mask_strict")
        self.backend = backend
        self._pallas_interpret = pallas_interpret
        self._pallas = None

    # -- backend resolution -------------------------------------------
    def resolve(self, n: int) -> str:
        """The backend a payload of ``n`` bytes actually runs on."""
        b = self.backend
        if b == "auto":
            if n < MIN_ACCEL_BYTES:
                return "numpy"     # dispatch overhead dominates below this
            return "pallas" if accelerator_present() else "jnp"
        if b == "pallas" and not (accelerator_present()
                                  or self._pallas_interpret):
            global _pallas_warned
            if not _pallas_warned:
                _pallas_warned = True
                warn("CDC_W_SCAN", "pallas scan backend requested but no "
                     "accelerator is present; falling back to the jnp "
                     "backend", backend="jnp")
            return "jnp"
        return b

    def _pallas_backend(self) -> _PallasBackend:
        if self._pallas is None:
            self._pallas = _PallasBackend(interpret=self._pallas_interpret)
        return self._pallas

    # -- scanning ------------------------------------------------------
    def scan(self, payload):
        return self.scan_async(payload).result()

    def scan_async(self, payload) -> ScanTicket:
        from collections import deque
        data = as_u8(payload)
        n = len(data)
        if n <= WINDOW:
            return ScanTicket(None, None, None, None,
                              done=(_EMPTY, _EMPTY))
        backend = self.resolve(n)
        if backend == "numpy":
            return ScanTicket(None, None, None, None,
                              done=scan_candidates_numpy(
                                  data, self.mask_strict, self.mask_loose))
        if backend == "pallas":
            eng = self._pallas_backend()
            raw_dispatch, extract = eng.dispatch, eng.extract
        else:
            raw_dispatch, extract = _JnpBackend.dispatch, _JnpBackend.extract

        def dispatch(start, seg_len):
            return raw_dispatch(data, start, seg_len, self.mask_strict,
                                self.mask_loose)

        spans = []
        pos = 0
        while pos < n:
            seg_len = min(SEGMENT_BYTES, n - pos)
            spans.append((pos, seg_len, n))
            pos += seg_len
        pending = deque(
            (dispatch(start, seg_len), start, seg_len, total)
            for start, seg_len, total in spans[:MAX_INFLIGHT_SEGMENTS])
        return ScanTicket(pending, spans[MAX_INFLIGHT_SEGMENTS:], dispatch,
                          extract)

    def scan_transform_async(self, payload, itemsize: int) \
            -> FusedScanTicket:
        """Dispatch the byteplane forward transform AND the candidate
        scan of the *transformed* stream as ONE device round-trip — the
        codec's pre-conditioning rides the scan dispatch the save queue
        already pays for. Below the acceleration threshold the host
        oracle runs both stages inline: same bytes, same candidates."""
        data = as_u8(payload)
        n = len(data)
        backend = self.resolve(n)
        if backend == "numpy" or n <= WINDOW:
            t = codec_mod.byteplane_forward(data, itemsize)
            done = (scan_candidates_numpy(t, self.mask_strict,
                                          self.mask_loose)
                    if n > WINDOW else (_EMPTY, _EMPTY))
            return FusedScanTicket(done=(done, t))
        import jax.numpy as jnp
        fn = _fused_fn(backend, self._pallas_interpret)
        raw = fn(jnp.asarray(data), int(itemsize), self.mask_strict,
                 self.mask_loose)
        if backend == "pallas":
            extract, res = _PallasBackend.extract, raw[1]
        else:
            extract, res = _JnpBackend.extract, raw[1:]

        def resolve():
            t = np.asarray(raw[0])
            return extract(res, 0, n, n), t

        return FusedScanTicket(resolve=resolve)

    def scan_transform_encode_async(self, payload, itemsize: int,
                                    entropy_codec: str) \
            -> FusedEncodeTicket:
        """Three fused stages in ONE device round-trip: byteplane forward
        transform, candidate scan of the transformed stream, and the
        plane RLE/rANS block encoder — chunks reach the host already
        compressed, so D2H and host hashing pay the encoded size. Below
        the acceleration threshold (or on the numpy backend) the host
        oracle runs all three stages inline: same bytes, same candidates,
        same encoded stream."""
        data = as_u8(payload)
        n = len(data)
        backend = self.resolve(n)
        if backend == "numpy" or n <= WINDOW:
            t = codec_mod.byteplane_forward(data, itemsize)
            cands = (scan_candidates_numpy(t, self.mask_strict,
                                           self.mask_loose)
                     if n > WINDOW else (_EMPTY, _EMPTY))
            stream, block_lens = codec_mod.plane_stream_encode(
                t, entropy_codec)
            return FusedEncodeTicket(done=(cands, stream, block_lens))
        import jax.numpy as jnp
        fn = _fused_fn(backend, self._pallas_interpret, entropy_codec)
        raw = fn(jnp.asarray(data), int(itemsize), self.mask_strict,
                 self.mask_loose)
        if backend == "pallas":
            extract, res = _PallasBackend.extract, raw[0]
            dlens, stream_dev, total = raw[2], raw[3], raw[4]
        else:
            extract, res = _JnpBackend.extract, raw[0:2]
            dlens, stream_dev, total = raw[3], raw[4], raw[5]

        def resolve():
            cands = extract(res, 0, n, n)
            tot = int(np.asarray(total))
            stream = np.asarray(stream_dev)[:tot]
            block_lens = 3 + np.asarray(dlens, np.int64)
            return cands, stream, block_lens

        return FusedEncodeTicket(resolve=resolve)
