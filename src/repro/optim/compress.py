"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantizes gradients before the DP reduction wire (4×/2× traffic
vs f32/bf16) and carries the quantization residual into the next step
(error feedback — Seide et al. / 1-bit-SGD lineage — which restores
convergence that naive quantization loses).

Under pure pjit the all-reduce is XLA-inserted and can't be intercepted, so
the Trainer applies this transform at the grad boundary (simulating the
compressed wire exactly — same numerics the shard_map DP loop would see);
the shard_map EP/DP paths can quantize around their explicit collectives
directly. The quantizer is the same codec as the checkpoint path
(kernels/ckpt_codec on TPU, core/codec semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quant_dequant(x):
    """Symmetric per-block int8 quantize→dequantize (jnp, jit-friendly)."""
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, BLOCK)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127)
    y = (q * scale[:, None]).reshape(-1)[:n]
    return y.reshape(shape)


class GradCompression:
    """Error-feedback int8 gradient compression."""

    def __init__(self, enabled: bool = True, min_size: int = 4096):
        self.enabled = enabled
        self.min_size = min_size  # tiny leaves (norms, biases) stay exact

    def init(self, params):
        zeros = lambda p: (jnp.zeros(p.shape, jnp.float32)
                           if p.size >= self.min_size else None)
        return {"error": jax.tree.map(zeros, params)}

    def apply(self, grads, state):
        """Returns (compressed-equivalent grads, new state)."""
        if not self.enabled:
            return grads, state

        def leaf(g, e):
            if e is None:
                return g, None
            corrected = g.astype(jnp.float32) + e
            g_hat = _quant_dequant(corrected)
            return g_hat.astype(g.dtype), corrected - g_hat

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(state["error"])
        out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
        new_g = tdef.unflatten([o[0] for o in out])
        new_e = tdef.unflatten([o[1] for o in out])
        return new_g, {"error": new_e}

    @staticmethod
    def wire_bytes(params) -> tuple:
        """(compressed, raw-f32) bytes per DP reduction."""
        comp = raw = 0
        for p in jax.tree.leaves(params):
            raw += p.size * 4
            comp += p.size + (p.size // BLOCK + 1) * 4
        return comp, raw
