"""Hand-rolled AdamW (no optax on the box). f32 moments, donated-friendly."""
from __future__ import annotations

import jax
import jax.numpy as jnp


class AdamW:
    def __init__(self, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                 clip_norm=1.0):
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params, lr):
        grads = clip_by_global_norm(grads, self.clip_norm)
        c = state["count"] + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** c.astype(jnp.float32)
        bc2 = 1.0 - b2 ** c.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if p.ndim >= 2:  # decoupled wd on matrices only
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "count": c}

    def state_sharding(self, param_specs, abstract_params, mesh):
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        return {
            "m": param_specs, "v": param_specs,
            "count": NamedSharding(mesh, P()),
        }


def clip_by_global_norm(grads, max_norm):
    if not max_norm:
        return grads
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads)
