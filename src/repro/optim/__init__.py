from .adafactor import Adafactor, make_optimizer
from .adamw import AdamW, clip_by_global_norm

__all__ = ["Adafactor", "AdamW", "clip_by_global_norm", "make_optimizer"]


def lr_schedule(step, *, peak=3e-4, warmup=100, total=10_000, floor=0.1):
    """Linear warmup + cosine decay to floor*peak."""
    import jax.numpy as jnp
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak * (step + 1) / warmup
    import jax
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)
