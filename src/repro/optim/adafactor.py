"""Hand-rolled Adafactor (factored second moment, no momentum).

Used for the 1T-class MoEs: optimizer state is ~O(rows+cols) per matrix
instead of 2× params, which is what lets kimi-k2 train on a 256-chip pod
(see EXPERIMENTS.md §Dry-run memory analysis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class Adafactor:
    def __init__(self, decay=0.99, eps=1e-30, clip_threshold=1.0,
                 min_dim_size_to_factor=32, weight_decay=0.0):
        self.decay = decay
        self.eps = eps
        self.clip_threshold = clip_threshold
        self.min_factor = min_dim_size_to_factor
        self.weight_decay = weight_decay

    def _factored(self, p):
        return (p.ndim >= 2 and p.shape[-1] >= self.min_factor
                and p.shape[-2] >= self.min_factor)

    def init(self, params):
        def leaf(p):
            if self._factored(p):
                return {
                    "v_row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "v_col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(leaf, params), "count": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params, lr):
        c = state["count"] + 1

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            if "v_row" in s:
                v_row = self.decay * s["v_row"] + (1 - self.decay) * g2.mean(-1)
                v_col = self.decay * s["v_col"] + (1 - self.decay) * g2.mean(-2)
                r = v_row / jnp.maximum(
                    v_row.mean(-1, keepdims=True), self.eps)
                u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(v_col)[..., None, :]
                         + 1e-12)
                new_s = {"v_row": v_row, "v_col": v_col}
            else:
                v = self.decay * s["v"] + (1 - self.decay) * g2
                u = g / (jnp.sqrt(v) + 1e-12)
                new_s = {"v": v}
            # update clipping (RMS <= threshold)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            pf = p.astype(jnp.float32)
            if self.weight_decay and p.ndim >= 2:
                u = u + self.weight_decay * pf
            return (pf - lr * u).astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["f"])
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_f = tdef.unflatten([o[1] for o in out])
        return new_p, {"f": new_f, "count": c}

    def state_sharding(self, param_specs, abstract_params, mesh):
        """Factored stats inherit the param spec with the reduced dim dropped."""
        import jax
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        def leaf_spec(sharding, p):
            shape = p.shape
            parts = list(sharding.spec) + [None] * (len(shape) - len(sharding.spec))
            if self._factored(p):
                return {
                    "v_row": NamedSharding(mesh, P(*parts[:-1])),
                    "v_col": NamedSharding(mesh, P(*(parts[:-2] + parts[-1:]))),
                }
            return {"v": NamedSharding(mesh, P(*parts))}

        f = jax.tree.map(leaf_spec, param_specs, abstract_params)
        return {"f": f, "count": NamedSharding(mesh, P())}


def make_optimizer(cfg):
    from .adamw import AdamW
    if cfg.optimizer == "adafactor":
        return Adafactor()
    return AdamW()
