"""Deterministic, checkpointable synthetic data pipeline.

The iterator state is pure data (seed, step, per-source counters) — part of
the checkpointed *upper half*. Restoring it reproduces the exact batch
sequence, which is what makes the bit-exact-resume test (paper's Gromacs
claim: "resumed to generate exactly the same results as an uninterrupted
run") possible.

Batches are generated with counter-based RNG (numpy Philox keyed on
(seed, step)) — O(1) skip-ahead, no hidden mutable state.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np


@dataclass(frozen=True)
class DataState:
    seed: int
    step: int
    # tokens drawn per mixture source (reliability metric + restore check)
    source_counts: tuple = ()

    def to_json(self):
        return {"seed": self.seed, "step": self.step,
                "source_counts": list(self.source_counts)}

    @staticmethod
    def from_json(d):
        return DataState(d["seed"], d["step"], tuple(d["source_counts"]))


class SyntheticPipeline:
    """Mixture-of-corpora synthetic LM/encoder batches.

    Each "source" is a different token distribution (Zipf-ish with distinct
    ranges) so mixture sampling — and its checkpointed counters — are
    observable in tests.
    """

    def __init__(self, cfg, *, batch, seq_len, mixture=(0.6, 0.3, 0.1)):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.mixture = np.asarray(mixture, np.float64)
        self.mixture /= self.mixture.sum()

    def init_state(self, seed=0):
        return DataState(seed=seed, step=0,
                         source_counts=(0,) * len(self.mixture))

    def _rng(self, state: DataState):
        # counter-based: (seed, step) fully determine the stream — O(1)
        # skip-ahead, restore-exact
        return np.random.Generator(
            np.random.Philox(key=[state.seed, state.step]))

    def next(self, state: DataState):
        rng = self._rng(state)
        B, S, V = self.batch, self.seq_len, self.cfg.vocab_size
        src = rng.choice(len(self.mixture), size=(B,), p=self.mixture)
        counts = list(state.source_counts)
        # per-source token ranges: source i draws from its own band of vocab
        bands = np.linspace(0, V, len(self.mixture) + 1).astype(np.int64)
        toks = np.empty((B, S), np.int32)
        for i in range(len(self.mixture)):
            rows = src == i
            n = int(rows.sum())
            if n == 0:
                continue
            counts[i] += n * S
            lo, hi = int(bands[i]), max(int(bands[i + 1]), int(bands[i]) + 1)
            # Zipf-flavored draw clipped into the band
            z = rng.zipf(1.3, size=(n, S)).astype(np.int64)
            toks[rows] = (lo + (z % max(hi - lo, 1))).astype(np.int32)
        new_state = replace(state, step=state.step + 1,
                            source_counts=tuple(counts))
        if self.cfg.family == "encoder":
            feats = rng.standard_normal((B, S, self.cfg.d_model),
                                        dtype=np.float32)
            mask = rng.random((B, S)) < 0.35
            return {"features": feats, "labels": toks % self.cfg.vocab_size,
                    "mask": mask}, new_state
        return {"tokens": toks % V}, new_state
