from .pipeline import DataState, SyntheticPipeline

__all__ = ["DataState", "SyntheticPipeline"]
